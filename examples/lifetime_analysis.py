"""CAMEL co-design analysis for a DuDNN configuration: per-layer data
lifetimes (eqs 3-10), the schedule simulation, the eDRAM refresh-free
verdict across temperature, and the TTA/ETA projection — the system-level
numbers come from the ``repro.sim`` arm/pipeline API.

    PYTHONPATH=src python examples/lifetime_analysis.py --blocks 6 --array 6
"""
import argparse

from repro import sim
from repro.core import edram as ed, lifetime as lt, schedule as sc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--blocks", type=int, default=6)
    ap.add_argument("--batch", type=int, default=48)
    ap.add_argument("--spatial", type=int, default=7)
    ap.add_argument("--branch-ch", type=int, default=48)
    ap.add_argument("--backbone-ch", type=int, default=160)
    ap.add_argument("--array", type=int, default=6)
    ap.add_argument("--temp", type=float, default=100.0)
    args = ap.parse_args()

    blocks = lt.duplex_block_specs(args.blocks, args.batch, args.spatial,
                                   args.branch_ch, args.backbone_ch)
    specs = [s for b in blocks for s in (b.f1, b.f2, b.g)]
    R = lt.array_throughput(args.array, 500e6, specs)
    print(f"effective throughput {args.array}×{args.array} @500MHz: "
          f"{R/1e9:.1f} GMAC/s")

    print("\nper-layer max data lifetime (closed forms, per-sample):")
    fwd = lt.forward_lifetimes(blocks, R)
    bwd = lt.backward_lifetimes(blocks, R)
    for l, (f, b) in enumerate(zip(fwd, bwd)):
        life = max(max(f.values()), max(b.values())) / args.batch
        print(f"  layer {l}: {life*1e6:8.3f} µs")

    fsim, bsim = sc.simulate_training_iteration(blocks, R)
    print(f"\nschedule simulation: fwd peak live "
          f"{fsim.peak_live_bits/8/1024:.1f} KiB, "
          f"bwd peak live {bsim.peak_live_bits/8/1024:.1f} KiB "
          f"(eDRAM capacity {ed.capacity_bits(ed.EDRAMConfig())/8/1024:.0f} KiB)")

    wl = dict(n_blocks=args.blocks, batch=args.batch, spatial=args.spatial,
              c_branch=args.branch_ch, c_backbone=args.backbone_ch)
    rep = sim.run(sim.get_arm("DuDNN+CAMEL").with_workload(**wl)
                  .with_system(array=args.array, temp_c=args.temp))
    ret = ed.retention_s(args.temp)
    print(f"\nmax lifetime {rep.max_lifetime_s*1e6:.3f} µs vs retention "
          f"{ret*1e6:.2f} µs @ {args.temp:.0f} °C → refresh-free: "
          f"{rep.refresh_free} "
          f"(margin {ed.refresh_margin(rep.max_lifetime_s, args.temp):.2f}×)")
    print(f"iteration: {rep.latency_s*1e3:.3f} ms, "
          f"{rep.energy_j*1e6:.1f} µJ "
          f"(compute {rep.compute_j*1e6:.1f} / memory {rep.memory_j*1e6:.1f})")

    sram = sim.run(sim.get_arm("FR+SRAM").with_workload(**wl))
    print(f"SRAM-only baseline: {sram.latency_s*1e3:.3f} ms, "
          f"{sram.energy_j*1e6:.1f} µJ, off-chip "
          f"{sram.offchip_bits/8/1024:.0f} KiB/iter "
          f"→ ETA advantage ≈ {sram.energy_j/rep.energy_j:.1f}×")


if __name__ == "__main__":
    main()
