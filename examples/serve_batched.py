"""Batched serving on the eDRAM KV-cache simulator (``repro.serve``).

Runs one serving arm end-to-end under seeded production-style traffic —
continuous batching, per-token KV-cache tensors living in the eDRAM
banks, the chosen KV policy deciding what happens when an entry's age
crosses the retention floor — and prints the ArmReport's serving
summary.  Optionally exports the flight-recorder trace (op/port/refresh
spans on the closed-loop timeline) as Chrome Trace Event JSON for
Perfetto, after reconciling it exactly against the report.

    PYTHONPATH=src python examples/serve_batched.py --policy skip \
        --rate 2e4 --batch 4 --trace serve.trace.json

See docs/serving.md for the policy semantics and the crossover story.
"""
import argparse

from repro import obs, sim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="skip",
                    choices=["always", "skip", "evict", "recompute"])
    ap.add_argument("--rate", type=float, default=2e4,
                    help="arrival rate, requests/s")
    ap.add_argument("--batch", type=int, default=4,
                    help="continuous-batching slots")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--temp", type=float, default=60.0,
                    help="die temperature, °C (sets eDRAM retention)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a reconciled Chrome/Perfetto trace")
    args = ap.parse_args()

    arm = (sim.get_arm(f"Serve/{args.policy}")
           .with_traffic(arrival_per_s=args.rate, max_batch=args.batch,
                         n_requests=args.requests, seed=args.seed)
           .with_system(temp_c=args.temp))
    rep = sim.run(arm, trace=args.trace is not None)

    s = rep.serving
    print(f"{arm.name} @ {args.rate:g} req/s, batch {args.batch}, "
          f"{args.temp:g}°C")
    print(f"  completed {s['requests_completed']}/{s['requests']} requests"
          f" ({s['requests_preempted']} preempted), "
          f"{s['tokens_served']} tokens decoded "
          f"(+{s['prefill_tokens']} prefilled)")
    print(f"  {s['tokens_per_s']:.0f} tok/s, {s['j_per_token']:.3e} J/tok, "
          f"latency p50/p95 = {s['latency_p50_s']*1e6:.1f}/"
          f"{s['latency_p95_s']*1e6:.1f} µs")
    print(f"  kv: {s['kv_entries_evicted']} evicted, "
          f"{s['kv_entries_recomputed']} recomputed, "
          f"{s['reads_dropped']} reads dropped, "
          f"restore_j={s['restore_j']:.3e}")
    print(f"  memory_j={rep.memory_j:.3e} stall_us={rep.stall_s*1e6:.2f} "
          f"refresh_free={rep.refresh_free}")

    if args.trace:
        res = obs.reconcile(rep.trace, rep)
        obs.export_chrome_trace(rep.trace, args.trace, report=rep)
        print(f"  trace: {args.trace} ({len(rep.trace.spans)} spans, "
              f"reconciled={res.ok})")


if __name__ == "__main__":
    main()
