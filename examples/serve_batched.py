"""Batched serving: prefill a batch of prompts, then greedy-decode
continuations with per-layer KV caches / recurrent states.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-780m
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import layers as L, registry
from repro.train import serve_step as ss

POLICY = L.Policy(compute_dtype=jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-9b",
                    choices=sorted(registry.ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    entry = registry.get(args.arch)
    cfg = entry.smoke                      # CPU-sized; entry.full on hardware
    params = entry.module.init_params(jax.random.PRNGKey(0), cfg)

    fe_shapes = entry.frontend_shape(cfg, args.batch)
    frontend = None if fe_shapes is None else {
        k: jax.random.normal(jax.random.PRNGKey(9), v) * 0.1
        for k, v in fe_shapes.items()}

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    max_len = args.prompt_len + args.gen + 8

    prefill = ss.make_prefill_step(entry, cfg, max_len=max_len, policy=POLICY,
                                   cache_dtype=jnp.float32,
                                   logits_mode="last")
    decode = jax.jit(ss.make_decode_step(entry, cfg, policy=POLICY))

    t0 = time.time()
    out = prefill(params, prompts, frontend) if frontend else \
        prefill(params, prompts)
    cache = out["cache"]
    tok = jnp.argmax(out["next_token_logits"], -1)[:, None].astype(jnp.int32)
    print(f"prefill[{args.batch}×{args.prompt_len}] "
          f"({args.arch} smoke): {time.time()-t0:.2f}s")

    seqs = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        tok, cache = decode(params, cache, tok)
        seqs.append(tok)
    gen = jnp.concatenate(seqs, axis=1)
    dt = time.time() - t0
    print(f"decoded {args.gen-1} steps in {dt:.2f}s "
          f"({(args.gen-1)*args.batch/dt:.1f} tok/s)")
    for b in range(args.batch):
        print(f"  seq{b}: {[int(t) for t in gen[b]]}")


if __name__ == "__main__":
    main()
