"""End-to-end training driver: Duplex-train an LM with the full substrate —
data pipeline (synthetic or byte corpus), checkpoint/restart, straggler
deadline, metrics.  Kill it mid-run and re-launch: it resumes from the last
published checkpoint at the exact batch index.

Default is a CPU-sized model; ``--d-model 768 --layers 12`` gives the
~100M-class configuration on real hardware.

    PYTHONPATH=src python examples/train_duplex_lm.py --steps 200
    PYTHONPATH=src python examples/train_duplex_lm.py --steps 400  # resumes
"""
import argparse

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointConfig
from repro.configs.common import LayerSpec, ModelConfig
from repro.core import duplex as dx
from repro.data.pipeline import DataConfig
from repro.models import layers as L, transformer as T
from repro.optim import AdamWConfig, cosine_warmup
from repro.train import loop, train_step as ts


class _Entry:
    module = T

    @staticmethod
    def frontend_shape(cfg, batch):
        return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--corpus", default=None,
                    help="path to a text file (byte-level LM); default synthetic")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_duplex_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    vocab = 256 if args.corpus else args.vocab
    cfg = ModelConfig(
        name="duplex-lm", family="dense", vocab=vocab,
        d_model=args.d_model, n_layers=args.layers,
        pattern=(LayerSpec("attn", "dense"),),
        n_heads=max(4, args.d_model // 64), n_kv=max(2, args.d_model // 128),
        head_dim=min(64, args.d_model // 4), d_ff=args.d_model * 4,
        vocab_pad_multiple=16,
    ).validate()
    policy = L.Policy(compute_dtype=jnp.float32)

    tcfg = ts.TrainConfig(
        mode="duplex",
        duplex=dx.DuplexConfig(
            n_blocks=2, d_branch=max(32, args.d_model // 4), pool_factor=8,
            branch_heads=2, bfp=L.BFPPolicy(enabled=True, group=(3, 3))),
        opt=AdamWConfig(weight_decay=0.01), lr=3e-3,
        lr_schedule=cosine_warmup(3e-3, warmup=20, total=args.steps),
        backbone_dtype=jnp.float32)

    entry = _Entry()
    train_step = jax.jit(ts.make_train_step(entry, cfg, tcfg, policy),
                         donate_argnums=0)
    data_cfg = DataConfig(
        vocab=vocab, seq_len=args.seq, batch_per_host=args.batch,
        kind="bytes" if args.corpus else "synthetic", path=args.corpus)
    loop_cfg = loop.LoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt=CheckpointConfig(args.ckpt_dir, keep=2),
        log_every=10, step_deadline_s=30.0)

    def step_fn(state, batch):
        return train_step(state, {k: jnp.asarray(v) for k, v in batch.items()})

    report = loop.run(
        loop_cfg, data_cfg, step_fn,
        init_state_fn=lambda: ts.init_state(jax.random.PRNGKey(0), entry,
                                            cfg, tcfg, policy))
    src = "resumed from step " + str(report.resumed_from) \
        if report.resumed_from else "fresh start"
    print(f"done ({src}): ran {report.steps_run} steps in "
          f"{report.wall_s:.1f}s; final "
          f"loss={report.metrics_history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
