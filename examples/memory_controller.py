"""Drive the bank-level eDRAM memory controller over one DuDNN training
iteration: per-bank occupancy, residency lifetimes vs retention, refresh
policy comparison, and the energy cross-check against the scalar model.

    PYTHONPATH=src python examples/memory_controller.py --temp 100 \
        --alloc lifetime
"""
import argparse

from repro.core import edram as ed, hwmodel as hw, lifetime as lt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--blocks", type=int, default=6)
    ap.add_argument("--batch", type=int, default=48)
    ap.add_argument("--branch-ch", type=int, default=48)
    ap.add_argument("--backbone-ch", type=int, default=160)
    ap.add_argument("--array", type=int, default=6)
    ap.add_argument("--temp", type=float, default=100.0)
    ap.add_argument("--alloc", default="lifetime",
                    choices=("pingpong", "first_fit", "lifetime"))
    args = ap.parse_args()

    blocks = lt.duplex_block_specs(args.blocks, args.batch, 7,
                                   args.branch_ch, args.backbone_ch)
    ret = ed.retention_s(args.temp)
    print(f"retention @ {args.temp:.0f}°C: {ret*1e6:.2f} µs\n")

    reports = {}
    for pol in ("none", "selective", "always"):
        cfg = hw.SystemConfig(array=args.array, temp_c=args.temp,
                              refresh_policy=pol, alloc_policy=args.alloc)
        reports[pol] = hw.iteration(cfg, blocks, reversible=True)

    c = reports["selective"].controller
    print(f"bank state under alloc={args.alloc!r}, policy='selective':")
    print(f"{'bank':>4} {'peak occ':>9} {'reads(kb)':>10} {'writes(kb)':>10} "
          f"{'max res(µs)':>12} {'needs?':>6} {'refreshed':>9} {'pulses':>6}")
    for b in c.banks:
        print(f"{b.index:>4} {b.peak_occupancy:>9.2f} "
              f"{b.read_bits/1e3:>10.1f} {b.write_bits/1e3:>10.1f} "
              f"{b.max_resident_lifetime_s*1e6:>12.3f} "
              f"{str(b.needs_refresh):>6} {str(b.refreshed):>9} "
              f"{b.refresh_count:>6}")

    print("\nrefresh policy comparison (one iteration):")
    for pol, rep in reports.items():
        cc = rep.controller
        print(f"  {pol:>9}: refresh={cc.refresh_j*1e9:9.2f} nJ  "
              f"memory={rep.memory_j*1e6:8.3f} µJ  "
              f"stall={rep.stall_s*1e6:7.1f} µs  safe={cc.safe}")

    rep = reports["selective"]
    if rep.scalar_memory_j > 0:
        err = abs(rep.memory_j - rep.scalar_memory_j) / rep.scalar_memory_j
        print(f"\nscalar-oracle cross-check: controller "
              f"{rep.memory_j*1e6:.3f} µJ vs scalar "
              f"{rep.scalar_memory_j*1e6:.3f} µJ (rel err {err:.1%})")
    if c.spilled_tensors:
        print(f"spilled off-chip: {', '.join(c.spilled_tensors)}")


if __name__ == "__main__":
    main()
