"""Drive the bank-level eDRAM memory controller over one DuDNN training
iteration via the ``repro.sim`` arm/pipeline API: per-bank occupancy,
residency lifetimes vs retention, refresh policy comparison, the energy
cross-check against the scalar model, and the FR/SRAM baseline replayed
through the same controller.

    PYTHONPATH=src python examples/memory_controller.py --temp 100 \
        --alloc lifetime
"""
import argparse

from repro import sim
from repro.core import edram as ed, hwmodel as hw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--blocks", type=int, default=6)
    ap.add_argument("--batch", type=int, default=48)
    ap.add_argument("--branch-ch", type=int, default=48)
    ap.add_argument("--backbone-ch", type=int, default=160)
    ap.add_argument("--array", type=int, default=6)
    ap.add_argument("--temp", type=float, default=100.0)
    ap.add_argument("--alloc", default="lifetime",
                    choices=("pingpong", "first_fit", "lifetime"))
    args = ap.parse_args()

    wl = sim.WorkloadSpec(n_blocks=args.blocks, batch=args.batch, spatial=7,
                          c_branch=args.branch_ch,
                          c_backbone=args.backbone_ch)
    ret = ed.retention_s(args.temp)
    print(f"retention @ {args.temp:.0f}°C: {ret*1e6:.2f} µs\n")

    reports = {}
    for pol in ("none", "selective", "always"):
        arm = sim.Arm(name=f"DuDNN+CAMEL/{pol}",
                      system=hw.SystemConfig(array=args.array,
                                             temp_c=args.temp,
                                             refresh_policy=pol,
                                             alloc_policy=args.alloc),
                      workload=wl, reversible=True, iters_to_target=None)
        reports[pol] = sim.run(arm)

    c = reports["selective"].memory
    print(f"bank state under alloc={args.alloc!r}, policy='selective':")
    print(f"{'bank':>4} {'peak occ':>9} {'reads(kb)':>10} {'writes(kb)':>10} "
          f"{'max res(µs)':>12} {'needs?':>6} {'refreshed':>9} {'pulses':>6}")
    for b in c["banks"]:
        print(f"{b['index']:>4} {b['peak_occupancy']:>9.2f} "
              f"{b['read_bits']/1e3:>10.1f} {b['write_bits']/1e3:>10.1f} "
              f"{b['max_resident_lifetime_s']*1e6:>12.3f} "
              f"{str(b['needs_refresh']):>6} {str(b['refreshed']):>9} "
              f"{b['refresh_count']:>6}")

    print("\nrefresh policy comparison (one iteration):")
    for pol, rep in reports.items():
        m = rep.memory
        print(f"  {pol:>9}: refresh={m['refresh_j']*1e9:9.2f} nJ "
              f"(read {m['refresh_read_j']*1e9:.2f} / "
              f"restore {m['refresh_restore_j']*1e9:.2f})  "
              f"memory={rep.memory_j*1e6:8.3f} µJ  "
              f"stall={rep.stall_s*1e6:7.1f} µs  safe={m['safe']}")

    rep = reports["selective"]
    if rep.scalar_memory_j > 0:
        print(f"\nscalar-oracle cross-check: controller "
              f"{rep.memory_j*1e6:.3f} µJ vs scalar "
              f"{rep.scalar_memory_j*1e6:.3f} µJ "
              f"(rel err {rep.oracle_rel_err:.1%})")
    if rep.memory["spilled"]:
        print(f"spilled off-chip: {', '.join(rep.memory['spilled'])}")

    # the irreversible baseline replays through the same controller: its
    # whole-iteration activation buffers spill one store + one load each
    fr = sim.run(sim.get_arm("FR+SRAM").with_workload(
        n_blocks=args.blocks, batch=args.batch, spatial=7,
        c_branch=args.branch_ch, c_backbone=args.backbone_ch))
    print(f"\nFR+SRAM baseline through the controller: "
          f"memory={fr.memory_j*1e6:.3f} µJ, "
          f"off-chip {fr.offchip_bits/8/1024:.0f} KiB/iter, "
          f"{len(fr.memory['spilled'])} buffers spilled, "
          f"oracle rel err {fr.oracle_rel_err:.1%}")


if __name__ == "__main__":
    main()
