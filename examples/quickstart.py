"""Quickstart: train a Duplex (frozen backbone + reversible branch) LM for a
few steps on CPU, then decode from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import duplex as dx
from repro.models import layers as L, registry
from repro.optim import AdamWConfig
from repro.train import serve_step as ss, train_step as ts

ARCH = "granite-3-8b"          # any of the 10 --arch ids
POLICY = L.Policy(compute_dtype=jnp.float32)


def main():
    entry = registry.get(ARCH)
    cfg = entry.smoke          # reduced config; entry.full is the real one

    tcfg = ts.TrainConfig(
        mode="duplex",
        duplex=dx.DuplexConfig(n_blocks=2, d_branch=32, pool_factor=4,
                               branch_heads=2,
                               bfp=L.BFPPolicy(enabled=True, group=(3, 3))),
        opt=AdamWConfig(weight_decay=0.0), lr=3e-3,
        backbone_dtype=jnp.float32)

    state = ts.init_state(jax.random.PRNGKey(0), entry, cfg, tcfg, POLICY)
    step = jax.jit(ts.make_train_step(entry, cfg, tcfg, POLICY))

    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    print(f"training the duplex branch on a fixed batch ({ARCH} smoke):")
    for i in range(10):
        state, m = step(state, batch)
        if i % 3 == 0 or i == 9:
            print(f"  step {i}: loss={float(m['loss']):.4f} "
                  f"acc={float(m['accuracy']):.3f}")

    # serve: prefill a prompt + greedy-decode 8 tokens from the backbone
    prefill = ss.make_prefill_step(entry, cfg, max_len=64, policy=POLICY,
                                   cache_dtype=jnp.float32)
    decode = ss.make_decode_step(entry, cfg, policy=POLICY)
    out = prefill(state["backbone"], tokens[:1, :16])
    cache = out["cache"]
    tok = jnp.argmax(out["next_token_logits"], -1)[:, None].astype(jnp.int32)
    generated = [int(tok[0, 0])]
    for _ in range(8):
        tok, cache = decode(state["backbone"], cache, tok)
        generated.append(int(tok[0, 0]))
    print("greedy continuation token ids:", generated)


if __name__ == "__main__":
    main()
