"""Pallas TPU kernels: standalone 2D-BFP (de)quantization.

These are the storage-path kernels: activations/gradients written to HBM in
packed BFP (int8 mantissas + per-group int8 exponents ≈ 8.25 bits/value vs
16 for bf16) — the TPU analogue of CAMEL's eDRAM density win (≥2× capacity,
§II-E), halving HBM traffic for every tensor that round-trips memory.

The packed matmul kernel consumes the quantized representation directly, so
the dequantized f32 tile exists only in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bfp_common import dequant_block, quant_block


def _quant_kernel(x_ref, mant_ref, exp_ref, *, g, mbits, ebits):
    mant, exp = quant_block(x_ref[...], g, mbits, ebits)
    mant_ref[...] = mant
    exp_ref[...] = exp


@functools.partial(
    jax.jit,
    static_argnames=("group", "mbits", "ebits", "block_m", "block_n", "interpret"),
)
def bfp_quantize_pallas(
    x: jax.Array,
    *,
    group: int = 32,
    mbits: int = 5,
    ebits: int = 4,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
):
    """Quantize a 2D f32 array → (mant int8, exp int8) in packed layout."""
    if x.ndim != 2:
        raise ValueError(f"expected 2D input, got {x.shape}")
    m, n = x.shape
    bm, bn = min(block_m, _ceil(m, group)), min(block_n, _ceil(n, group))
    mp, np_ = _ceil(m, bm), _ceil(n, bn)
    x = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, np_ - n)))

    mant, exp = pl.pallas_call(
        functools.partial(_quant_kernel, g=group, mbits=mbits, ebits=ebits),
        grid=(mp // bm, np_ // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm // group, bn // group), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.int8),
            jax.ShapeDtypeStruct((mp // group, np_ // group), jnp.int8),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x)
    return mant, exp


def _packed_matmul_kernel(am_ref, ae_ref, bm_ref, be_ref, o_ref, acc_ref,
                          *, g, mbits):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = dequant_block(am_ref[...], ae_ref[...], g, mbits)
    b = dequant_block(bm_ref[...], be_ref[...], g, mbits)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _drain():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group", "mbits", "block_m", "block_n", "block_k",
                     "interpret", "out_dtype"),
)
def bfp_matmul_packed(
    a_mant: jax.Array, a_exp: jax.Array,
    b_mant: jax.Array, b_exp: jax.Array,
    *,
    group: int = 32,
    mbits: int = 5,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Matmul on pre-quantized packed operands (mant/exp from the quantizer).

    HBM reads are ~2× lighter than bf16; the dequantized tiles live only in
    VMEM — this is the eDRAM-as-activation-store dataflow of CAMEL mapped to
    the TPU memory hierarchy.
    """
    (m, k), (k2, n) = a_mant.shape, b_mant.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a_mant.shape} @ {b_mant.shape}")
    if m % group or k % group or n % group:
        raise ValueError("packed operands must already be group-padded")
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"dims {(m, k, n)} must tile by blocks {(bm, bk, bn)}")

    gspec = lambda d1, d2, idx: pl.BlockSpec((d1 // group, d2 // group), idx)
    grid = (m // bm, n // bn, k // bk)
    out = pl.pallas_call(
        functools.partial(_packed_matmul_kernel, g=group, mbits=mbits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            gspec(bm, bk, lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            gspec(bk, bn, lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_mant, a_exp, b_mant, b_exp)
    return out


def _ceil(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
