"""Pallas TPU kernel: fused causal flash attention with GQA.

The §Perf forensics (EXPERIMENTS.md H3) show the XLA-level blockwise
attention materializes every [qc, kc] score block + f32 accumulator to HBM —
~2.7 TB/device for starcoder2 prefill_32k.  This kernel keeps scores, the
online-softmax state (m, l), and the output accumulator in VMEM scratch;
only q/k/v/o stream HBM.

Grid: (B, H, nq, nk) with the kv dimension innermost+sequential (the same
accumulation-stationary pattern as the BFP matmul kernel).  GQA is handled
by the k/v BlockSpec index maps (kv head = h // group), so the expanded KV
never exists in memory.  Causal skipping is structural: fully-masked kv
blocks execute nothing.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; accept both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  q_chunk, kv_chunk, softcap, causal, scale):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * q_chunk
    k_start = ik * kv_chunk
    # causal structural skip: block computes only if any (q >= k) pair exists
    live = jnp.logical_or(not causal,
                          q_start + q_chunk - 1 >= k_start)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [qc, d]
        k = k_ref[0, 0].astype(jnp.float32)          # [kc, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (q_chunk, kv_chunk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (q_chunk, kv_chunk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "softcap", "q_chunk", "kv_chunk", "interpret"))
def flash_attention(
    q: jax.Array,            # [B, H, Sq, d]
    k: jax.Array,            # [B, KV, Skv, d]
    v: jax.Array,            # [B, KV, Skv, d]
    *,
    causal: bool = True,
    softcap: float | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused flash attention; returns [B, H, Sq, d] in q.dtype."""
    b, h, sq, d = q.shape
    _, nkv, skv, _ = k.shape
    if h % nkv:
        raise ValueError(f"{h} query heads not a multiple of {nkv} kv heads")
    g = h // nkv
    if sq % q_chunk or skv % kv_chunk:
        raise ValueError(f"seq lens {(sq, skv)} must tile by chunks "
                         f"{(q_chunk, kv_chunk)}")
    grid = (b, h, sq // q_chunk, skv // kv_chunk)
    scale = 1.0 / math.sqrt(d)

    return pl.pallas_call(
        functools.partial(_flash_kernel, q_chunk=q_chunk, kv_chunk=kv_chunk,
                          softcap=softcap, causal=causal, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_chunk, d),
                         lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
            pl.BlockSpec((1, 1, kv_chunk, d),
                         lambda bb, hh, qq, kk, g=g: (bb, hh // g, kk, 0)),
            pl.BlockSpec((1, 1, kv_chunk, d),
                         lambda bb, hh, qq, kk, g=g: (bb, hh // g, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_chunk, d),
                               lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_chunk, d), jnp.float32),
            pltpu.VMEM((q_chunk,), jnp.float32),
            pltpu.VMEM((q_chunk,), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
