"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bfp


def ref_bfp_matmul(a, b, *, group=32, mbits=5, ebits=4):
    """Oracle for kernels.bfp_matmul: global square-group qdq then f32 matmul.

    Valid as an oracle because kernel blocks are multiples of the group and
    blocks tile the operand from the (0,0) origin, so in-block groups coincide
    with the global group grid and zero padding never changes a group max.
    """
    aq = bfp.bfp_dequantize(bfp.bfp_quantize(
        a.astype(jnp.float32), group=(group, group), ebits=ebits, mbits=mbits))
    bq = bfp.bfp_dequantize(bfp.bfp_quantize(
        b.astype(jnp.float32), group=(group, group), ebits=ebits, mbits=mbits))
    return jnp.matmul(aq, bq, precision=jax.lax.Precision.HIGHEST)


def ref_bfp_quantize(x, *, group=32, mbits=5, ebits=4):
    """Oracle for kernels.bfp_quantize_pallas (packed mant/exp layout)."""
    t = bfp.bfp_quantize(x.astype(jnp.float32), group=(group, group),
                         ebits=ebits, mbits=mbits)
    return t.mant, t.exp


def ref_bfp_matmul_packed(a_mant, a_exp, b_mant, b_exp, *, group=32, mbits=5):
    """Oracle for kernels.bfp_matmul_packed."""
    def deq(mant, exp):
        m, n = mant.shape
        t = bfp.BFPTensor(mant=mant, exp=exp, shape=(m, n),
                          group=(group, group), mbits=mbits)
        return bfp.bfp_dequantize(t)
    return jnp.matmul(deq(a_mant, a_exp), deq(b_mant, b_exp),
                      precision=jax.lax.Precision.HIGHEST)
