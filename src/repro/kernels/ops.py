"""Jit'd public wrappers around the Pallas kernels.

``bfp_dense`` is the training-facing op: a linear layer whose forward AND
backward matmuls run the BFP kernel.  The backward pass consumes transposed
operands (Table I: ∇A = ∇O·Wᵀ, ∇W = Aᵀ·∇O) — with *square* 2D BFP groups the
transposed quantization is exactly the transpose of the forward quantization
(Q(Wᵀ)=Q(W)ᵀ), so no re-quantization semantics change between passes; this is
the paper's §III-E property realized end-to-end.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.bfp_matmul import bfp_matmul
from repro.kernels.bfp_quant import bfp_matmul_packed, bfp_quantize_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class BFPKernelConfig:
    group: int = 32
    mbits: int = 5
    ebits: int = 4
    block_m: int = 256
    block_n: int = 256
    block_k: int = 256
    # None → interpret automatically off on TPU, on elsewhere (CPU validation).
    interpret: bool | None = None

    @property
    def run_interpret(self) -> bool:
        return (not on_tpu()) if self.interpret is None else self.interpret


def matmul(a: jax.Array, b: jax.Array, cfg: BFPKernelConfig = BFPKernelConfig()):
    return bfp_matmul(
        a, b, group=cfg.group, mbits=cfg.mbits, ebits=cfg.ebits,
        block_m=cfg.block_m, block_n=cfg.block_n, block_k=cfg.block_k,
        interpret=cfg.run_interpret)


def quantize(x: jax.Array, cfg: BFPKernelConfig = BFPKernelConfig()):
    return bfp_quantize_pallas(
        x, group=cfg.group, mbits=cfg.mbits, ebits=cfg.ebits,
        block_m=cfg.block_m, block_n=cfg.block_n, interpret=cfg.run_interpret)


def matmul_packed(a_mant, a_exp, b_mant, b_exp,
                  cfg: BFPKernelConfig = BFPKernelConfig()):
    return bfp_matmul_packed(
        a_mant, a_exp, b_mant, b_exp, group=cfg.group, mbits=cfg.mbits,
        block_m=cfg.block_m, block_n=cfg.block_n, block_k=cfg.block_k,
        interpret=cfg.run_interpret)


# --------------------------------------------------------------------------
# bfp_dense: linear layer with BFP forward and BFP backward (Table I).
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2,))
def bfp_dense(x: jax.Array, w: jax.Array, cfg: BFPKernelConfig) -> jax.Array:
    """``x @ w`` with both operands 2D-BFP quantized, kernel-backed.

    x: (..., K), w: (K, N) → (..., N).
    """
    return _bfp_dense_fwd(x, w, cfg)[0]


def _flatten_lead(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _bfp_dense_fwd(x, w, cfg):
    x2, lead = _flatten_lead(x)
    y = matmul(x2, w, cfg)
    return y.reshape(*lead, w.shape[-1]).astype(x.dtype), (x, w)


def _bfp_dense_bwd(cfg, res, g):
    x, w = res
    x2, lead = _flatten_lead(x)
    g2, _ = _flatten_lead(g)
    # ∇A = ∇O · Wᵀ ;  ∇W = Aᵀ · ∇O  — both through the BFP kernel, with the
    # transposed operand quantization inherited via square-group invariance.
    dx = matmul(g2.astype(jnp.float32), w.astype(jnp.float32).T, cfg)
    dw = matmul(x2.astype(jnp.float32).T, g2.astype(jnp.float32), cfg)
    return dx.reshape(x.shape).astype(x.dtype), dw.astype(w.dtype)


bfp_dense.defvjp(_bfp_dense_fwd, _bfp_dense_bwd)
