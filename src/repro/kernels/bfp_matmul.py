"""Pallas TPU kernel: fused 2D-BFP matmul (CAMEL's BFP PE array on the MXU).

Computes ``Q(A) @ Q(B)`` where ``Q`` is square-group 2D BFP quantization
(§III-E).  Operands are quantized *inside* the kernel at the VMEM tile
boundary — the TPU analogue of CAMEL's PE-edge BFP conversion — so only
full-precision tiles stream HBM→VMEM and no quantized copy is materialized.

Dataflow (DESIGN.md §2): the K-innermost grid with a VMEM f32 accumulator is
the accumulation-stationary schedule of Fig 17(c); the A-block is re-used
across the N-loop like a stationary weight in Fig 17(a).

Grid:  (M/bm, N/bn, K/bk), K innermost ("arbitrary" semantics → sequential).
BlockSpecs: A (bm,bk) @ (i,k) · B (bk,bn) @ (k,j) → O (bm,bn) @ (i,j).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bfp_common import qdq_block


def _bfp_matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, g, mbits, ebits,
                       skip_zero_groups):
    """One (i, j, k) grid step: acc += Q(A[i,k]) @ Q(B[k,j])."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = qdq_block(a_ref[...], g, mbits, ebits)
    b = qdq_block(b_ref[...], g, mbits, ebits)

    if skip_zero_groups:
        # CAMEL's first gating checkpoint (§V-B): skip the MAC entirely when
        # one operand tile is all-zero.  On the MXU this is a tile-level (not
        # per-element) skip — the closest structural analogue.
        nonzero = jnp.logical_and(jnp.any(a != 0.0), jnp.any(b != 0.0))

        @pl.when(nonzero)
        def _mac():
            acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)
    else:
        acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _drain():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group", "mbits", "ebits", "block_m", "block_n",
                     "block_k", "skip_zero_groups", "interpret", "out_dtype"),
)
def bfp_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    group: int = 32,
    mbits: int = 5,
    ebits: int = 4,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    skip_zero_groups: bool = False,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """``Q(a) @ Q(b)`` with square-group 2D BFP operands.

    ``a``: (M, K), ``b``: (K, N).  Dims are padded to block multiples; blocks
    are multiples of ``group`` so in-block groups coincide with the global
    group grid (zero padding never raises a group max).
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"expected 2D operands, got {a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    for blk in (block_m, block_n, block_k):
        if blk % group:
            raise ValueError(f"block size {blk} not a multiple of group {group}")

    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = (min(block_m, _ceil(m, group)), min(block_n, _ceil(n, group)),
                  min(block_k, _ceil(k, group)))
    mp, kp, np_ = _ceil(m, bm), _ceil(k, bk), _ceil(n, bn)
    a = jnp.pad(a.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    b = jnp.pad(b.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_bfp_matmul_kernel, g=group, mbits=mbits, ebits=ebits,
                          skip_zero_groups=skip_zero_groups),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


def _ceil(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
