"""Shared in-kernel helpers for the BFP Pallas kernels.

Everything here must lower on Mosaic/TPU: exponent extraction uses an integer
bitcast (`floor(log2|x|)` = biased exponent − 127 for normalized floats)
instead of `frexp`, which the TPU backend does not provide.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32_EXP_BIAS = 127


def floor_log2(x: jax.Array) -> jax.Array:
    """floor(log2(x)) for x >= 0 (f32), elementwise; x == 0 → -127."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    e = jnp.right_shift(bits, 23) & 0xFF
    e = e - F32_EXP_BIAS
    return jnp.where(x > 0, e, jnp.full_like(e, -F32_EXP_BIAS))


def group_exponent(x: jax.Array, g: int, ebits: int) -> jax.Array:
    """Shared exponent per (g×g) group of a 2D block; shape (M/g, 1, N/g, 1)."""
    bm, bn = x.shape
    xg = x.reshape(bm // g, g, bn // g, g)
    amax = jnp.max(jnp.abs(xg), axis=(1, 3), keepdims=True)
    e = floor_log2(amax)
    lo, hi = -(2 ** (ebits - 1)), 2 ** (ebits - 1) - 1
    return jnp.clip(e, lo, hi)


def qdq_block(x: jax.Array, g: int, mbits: int, ebits: int) -> jax.Array:
    """Quantize→dequantize a 2D f32 block with square (g×g) BFP groups.

    This is the PE-boundary quantization of the CAMEL systolic array mapped to
    a VMEM-resident tile: operands are quantized as they enter the MXU, so no
    quantized copy ever round-trips HBM.
    """
    bm, bn = x.shape
    x = x.astype(jnp.float32)
    e = group_exponent(x, g, ebits)
    xg = x.reshape(bm // g, g, bn // g, g)
    scale = jnp.exp2((e - (mbits - 1)).astype(jnp.float32))
    lim = float(2**mbits - 1)
    m = jnp.clip(jnp.round(xg / scale), -lim, lim)
    return (m * scale).reshape(bm, bn)


def quant_block(x: jax.Array, g: int, mbits: int, ebits: int):
    """Quantize a 2D block → (mant int8 [bm,bn], exp int8 [bm/g,bn/g])."""
    bm, bn = x.shape
    x = x.astype(jnp.float32)
    e = group_exponent(x, g, ebits)
    xg = x.reshape(bm // g, g, bn // g, g)
    scale = jnp.exp2((e - (mbits - 1)).astype(jnp.float32))
    lim = float(2**mbits - 1)
    m = jnp.clip(jnp.round(xg / scale), -lim, lim)
    mant = m.reshape(bm, bn).astype(jnp.int8)
    exp = e.reshape(bm // g, bn // g).astype(jnp.int8)
    return mant, exp


def dequant_block(mant: jax.Array, exp: jax.Array, g: int, mbits: int) -> jax.Array:
    bm, bn = mant.shape
    mg = mant.reshape(bm // g, g, bn // g, g).astype(jnp.float32)
    e = exp.astype(jnp.float32)[:, None, :, None]
    return (mg * jnp.exp2(e - (mbits - 1))).reshape(bm, bn)
