"""Tensor-to-bank placement engine (CAMEL §V-B, Fig 17).

Placement strategy is pluggable: the classic policies (``pingpong`` /
``first_fit`` / ``lifetime``, see :mod:`repro.memory.tiers` for their
definitions) are resolved through
:func:`repro.memory.tiers.resolve_placement_policy`, and a
:class:`~repro.memory.tiers.MemorySystem` composes one allocator per
memory tier behind this same interface.

A tensor may stripe across several banks; when no combination of free
words can hold it, the whole tensor spills off-chip (partial spills would
split a BFP group's shared exponent from its mantissas).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.memory.banks import BankGeometry, BankState
from repro.memory.tiers import ALLOC_POLICIES, resolve_placement_policy

OFFCHIP = "offchip"


@dataclasses.dataclass(frozen=True)
class Placement:
    """Where a tensor lives: ``spans`` of (bank index, words), or off-chip."""
    tensor: str
    bits: float
    spans: tuple          # ((bank_idx, words), ...); empty when spilled
    expected_lifetime_s: Optional[float] = None

    @property
    def offchip(self) -> bool:
        return not self.spans


class Allocator:
    """Places tensors into banks; tracks spills and placement history."""

    def __init__(self, geometry: BankGeometry, policy: str = "pingpong",
                 retention_s: Optional[float] = None):
        self._policy = resolve_placement_policy(policy)
        self.geometry = geometry
        self.policy = self._policy.name
        self.retention_s = retention_s
        self.banks = [BankState(i, geometry) for i in range(geometry.n_banks)]
        self.placements: dict[str, Placement] = {}
        self.spill_bits = 0.0
        self.spilled: list[str] = []
        self.evicted: list[str] = []
        self._next_bank = 0

    # -- policy: bank visit order ----------------------------------------
    def _tiers(self, expected_lifetime_s: Optional[float]) -> list[list]:
        """Bank positions in placement-preference groups (delegates to the
        resolved :class:`~repro.memory.tiers.PlacementPolicy`).  Striping
        spreads a tensor across one group before touching the next, so the
        lifetime policy keeps its coloring while still winning port
        bandwidth."""
        return self._policy.bank_order(self, expected_lifetime_s)

    # -- allocation ------------------------------------------------------
    def place(self, tensor: str, bits: float, now: float,
              expected_lifetime_s: Optional[float] = None,
              lifetime_scale: float = 1.0,
              reserve_words: int = 0) -> Placement:
        """Allocate ``tensor`` into banks; spills the *whole* tensor
        off-chip when capacity is exceeded (partial spills would split a
        BFP group's shared exponent from its mantissas).

        Args:
            tensor: unique name; placing an already-placed tensor raises
                ``ValueError`` (use :meth:`rewrite` for overwrites).
            bits: storage footprint in **bits** (already per-sample
                scaled by the caller when the tensor streams); rounded
                up to whole 58-bit words.
            now: placement time in **seconds** on the trace timeline.
            expected_lifetime_s: predicted write→free window in
                **seconds** (data lifetime, i.e. already
                ``lifetime_scale``-scaled); steers the ``lifetime``
                coloring policy.  ``None`` means unknown → treated as
                short-lived.
            lifetime_scale: residency-to-data-lifetime factor recorded
                on the bank residency (1/batch for per-sample streamed
                tensors, 1.0 for whole-iteration buffers).
            reserve_words: headroom floor in **words** this placement
                must leave free: the trace replay passes the streamed
                working set's remaining peak when placing
                whole-iteration buffers, so a low-priority buffer spills
                instead of later evicting the dataflow's live tensors.

        Returns:
            The :class:`Placement` — ``spans`` of ``(bank index,
            words)``, or empty spans (``offchip == True``) on spill.
            Spills also increment ``spill_bits``/``spilled``.
        """
        if tensor in self.placements:
            raise ValueError(f"{tensor} already placed")
        need = self.geometry.words_for(bits)
        tiers = self._tiers(expected_lifetime_s)
        flat = [i for tier in tiers for i in tier]
        free_total = sum(self.banks[i].free_words for i in flat) \
            - max(0, reserve_words)
        if need > free_total:
            self.spill_bits += bits
            self.spilled.append(tensor)
            p = Placement(tensor, bits, spans=(),
                          expected_lifetime_s=expected_lifetime_s)
            self.placements[tensor] = p
            return p
        # dense packing serves the policies that minimize footprint (the
        # lifetime policy packs over-retention tensors densely so they
        # poison as few banks as possible — those banks refresh; the rest
        # stay refresh-free); otherwise tensors stripe for bandwidth
        dense = self._policy.dense(self, expected_lifetime_s)
        takes: dict[int, int] = {}
        remaining = need
        for tier in tiers:
            if remaining == 0:
                break
            if dense:
                # dense packing: fill banks in order (worst port conflicts)
                for i in tier:
                    if remaining == 0:
                        break
                    take = min(remaining, self.banks[i].free_words)
                    if take:
                        takes[i] = take
                        remaining -= take
            else:
                # striped: spread words evenly across the tier's banks so
                # reads draw one word/cycle from many ports at once
                # (Fig 17's bandwidth story)
                while remaining > 0:
                    active = [i for i in tier
                              if self.banks[i].free_words > takes.get(i, 0)]
                    if not active:
                        break
                    share = -(-remaining // len(active))        # ceil
                    for i in active:
                        room = self.banks[i].free_words - takes.get(i, 0)
                        take = min(share, room, remaining)
                        if take:
                            takes[i] = takes.get(i, 0) + take
                            remaining -= take
                        if remaining == 0:
                            break
        spans = []
        for i in flat:
            if i in takes:
                self.banks[i].allocate(tensor, takes[i], now,
                                       scale=lifetime_scale)
                spans.append((i, takes[i]))
        self._policy.placed(self, spans)
        p = Placement(tensor, bits, spans=tuple(spans),
                      expected_lifetime_s=expected_lifetime_s)
        self.placements[tensor] = p
        return p

    def rewrite(self, tensor: str, now: float) -> Placement:
        """Overwrite in place (dead value reuse, Fig 12c)."""
        p = self.placements[tensor]
        for i, _ in p.spans:
            self.banks[i].rewrite(tensor, now)
        return p

    def free(self, tensor: str, now: float) -> None:
        p = self.placements.pop(tensor, None)
        if p is None:
            return
        for i, _ in p.spans:
            self.banks[i].free(tensor, now)

    def touch(self, tensor: str, now: float) -> None:
        """Read-triggered restore over every bank the tensor stripes
        across (see :meth:`BankState.touch`); off-chip or unknown tensors
        are a no-op — there is nothing decaying to restore."""
        p = self.placements.get(tensor)
        if p is None:
            return
        for i, _ in p.spans:
            self.banks[i].touch(tensor, now)

    def evict(self, tensor: str, now: float) -> None:
        """Policy-driven drop: release the tensor's words like
        :meth:`free`, but record it in ``evicted`` — the data was dropped
        *before* its last reader (a KV entry past its retention deadline,
        a preempted serving session), which ``repro.serve`` counts as its
        accuracy proxy."""
        if tensor in self.placements:
            self.evicted.append(tensor)
        self.free(tensor, now)

    # -- introspection ---------------------------------------------------
    def location(self, tensor: str) -> Optional[Placement]:
        return self.placements.get(tensor)

    @property
    def used_bits(self) -> float:
        return sum(b.occupied_bits for b in self.banks)

    def occupancy(self) -> list[float]:
        """Per-bank fill fraction (words used / words per bank)."""
        w = self.geometry.words_per_bank
        return [b.used_words / w for b in self.banks]
