"""Memory-trace records and trace-driven controller replay (CAMEL §V).

``core.schedule.simulate()`` emits one :class:`TraceEvent` per tensor
touch (alloc/write/read/free, timestamped on the op timeline).  ``replay``
drives the full controller — allocator placement, per-bank occupancy and
port contention, per-bank refresh — over that trace and returns a
:class:`ControllerReport` that ``core.hwmodel.iteration()`` consumes in
place of the scalar ``stored``/``needs_refresh`` arithmetic.

Per-sample normalization: the weight-stationary dataflow streams the
mini-batch sample-by-sample through ping-pong buffers (Fig 17a), so a
tensor's *buffer* is per-sample sized and persists for the whole
producer→consumer window, while its *data* lifetime is that window divided
by the batch.  ``replay(sample_scale=batch)`` therefore places
``bits/batch`` into banks, charges traffic energy on the full ``bits``,
and compares residency × ``1/batch`` against retention — exactly the
accounting that fits batch-48 training under a 3.4 µs retention (Fig 23a).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core import edram as ed
from repro.core.schedule import EVENT_KINDS, TraceEvent
from repro.memory.allocator import Allocator
from repro.memory.banks import BankGeometry, port_service_s
from repro.memory.refresh import RefreshScheduler
from repro.memory.tiers import MemorySystem

# trace-replay engines: "python" is the scalar reference walk below;
# "vector" is the numpy interval engine (repro.memory.vector), bit-
# identical on every report field and ~an order of magnitude faster on
# long traces.
REPLAY_BACKENDS = ("python", "vector")


def resolve_backend(backend: str, recorder=None, tiers=None) -> str:
    """Validate ``backend`` and resolve it against the run's features:
    span recording observes the scalar walk's side effects (per-event
    occupancy counters, spill spans), which the vector engine batches
    away, and a tiered memory system routes tensors through the
    :class:`~repro.memory.tiers.MemorySystem` the vector engine does not
    model — either downgrades ``"vector"`` to the reference path with a
    logged warning rather than silently dropping the feature."""
    if backend not in REPLAY_BACKENDS:
        raise ValueError(f"unknown replay backend {backend!r}; "
                         f"choose from {REPLAY_BACKENDS}")
    if backend == "vector" and recorder is not None:
        from repro.obs import log as obslog
        obslog.warn("replay_backend_downgrade", requested="vector",
                    used="python",
                    reason="span_recording_needs_reference_walk")
        return "python"
    if backend == "vector" and tiers:
        from repro.obs import log as obslog
        obslog.warn("replay_backend_downgrade", requested="vector",
                    used="python",
                    reason="tiered_memory_system_needs_reference_walk")
        return "python"
    return backend


def merge_traces(fwd, bwd) -> tuple[list[TraceEvent], dict, float]:
    """Concatenate forward + backward ``SimResult`` traces onto one
    timeline; returns (events, op_durations, total_time)."""
    events = list(fwd.trace)
    offset = fwd.total_time
    durations = {name: end - start for name, start, end in fwd.schedule}
    for name, start, end in bwd.schedule:
        durations[name] = end - start
    for ev in bwd.trace:
        # tensors already resident from the forward pass (b1_L, b2_L, …)
        # must not be re-allocated by the backward trace's boot events
        events.append(dataclasses.replace(ev, time=ev.time + offset))
    return events, durations, fwd.total_time + bwd.total_time


@dataclasses.dataclass(frozen=True)
class BankReport:
    """Per-bank breakdown consumed by benchmarks and tests."""
    index: int
    read_bits: float
    write_bits: float
    refresh_bits: float            # bit-intervals actually refreshed
    refresh_count: int
    refresh_j: float
    stall_s: float
    peak_words: int
    peak_occupancy: float          # peak_words / words_per_bank
    max_resident_lifetime_s: float  # per-sample (already scaled)
    needs_refresh: bool
    refreshed: bool
    # timeline model only (zero under the additive model)
    busy_s: float = 0.0            # port-busy time on the event timeline
    refresh_hidden: int = 0        # pulses placed into idle windows
    # this bank's refresh pulse is longer than its retention interval —
    # it can never hide under compute (see RefreshScheduler.account)
    pulse_exceeds_retention: bool = False
    # row-granular pulses emitted for this bank (0 under bank granularity)
    rows_refreshed: int = 0


@dataclasses.dataclass(frozen=True)
class ControllerReport:
    """What the controller did over one iteration's trace.

    ``stall_s`` is the total array-visible serialization added to the
    schedule: ``conflict_stall_s`` (bank-port contention) plus
    ``refresh_stall_s`` (refresh pulses that could not hide under
    compute).  Under the additive model every pulse stalls; under the
    timeline model only pulses with no bank-idle window do, and the
    energy of the hidden ones is surfaced as ``refresh_hidden_j``
    (charged in ``refresh_j`` as always — hiding saves time, not energy).
    """
    refresh_policy: str
    alloc_policy: str
    temp_c: float
    duration_s: float
    banks: tuple                   # BankReport per bank
    read_j: float
    write_j: float
    refresh_j: float
    offchip_j: float
    stall_s: float
    spill_bits: float              # capacity-overflow bits (per-sample)
    offchip_bits: float            # traffic to/from spilled tensors
    spilled_tensors: tuple
    # read-triggered restore (reads_restore=True, repro.serve KV
    # policies): the write-back share of each on-chip read, already
    # *included* in read_j — informational split, like refresh_read_j.
    restore_j: float = 0.0
    # tensors dropped by ``evict`` events before their last reader
    evicted_tensors: tuple = ()
    refresh_read_j: float = 0.0    # refresh sense phase (sums to refresh_j
    refresh_restore_j: float = 0.0  # with the restore/write-back phase)
    # the wall-clock retention floor / refresh interval the scheduler ran
    # with — invariant under frequency scaling; both are math.inf on SRAM
    # replays (never refresh) and serialize as null in the JSON form
    retention_s: float = 0.0
    interval_s: float = 0.0
    timing: str = "additive"       # additive | timeline
    conflict_stall_s: float = 0.0  # bank/port contention share of stall_s
    refresh_stall_s: float = 0.0   # unhidden-refresh share of stall_s
    refresh_hidden_j: float = 0.0  # refresh energy hidden under compute
    timeline: Optional[dict] = None  # timeline-model summary (JSON-safe)
    # pulse granularity the scheduler ran with ("bank" | "row"); under
    # "row", rows_refreshed counts the row pulses emitted and
    # row_hidden_frac the share of them placed into idle gaps (both stay
    # 0 under bank granularity).  Refresh *energy* is granularity-
    # invariant — only refresh_stall_s / refresh_hidden_j move.
    granularity: str = "bank"
    rows_refreshed: int = 0
    row_hidden_frac: float = 0.0
    # per-tier breakdown (hybrid SRAM+eDRAM replays only): one JSON-safe
    # summary dict per TierSpec, in tier order — empty tuple on the
    # classic single-tier replays so their serialized form is unchanged.
    # Tier read/write/restore/refresh energies sum exactly to the report
    # totals (the totals are computed as the fold of the per-tier sums).
    tiers: tuple = ()

    @property
    def energy(self) -> ed.MemoryEnergy:
        return ed.MemoryEnergy(read_j=self.read_j, write_j=self.write_j,
                               refresh_j=self.refresh_j,
                               offchip_j=self.offchip_j)

    @property
    def refresh_count(self) -> int:
        return sum(b.refresh_count for b in self.banks)

    @property
    def safe(self) -> bool:
        """No silent data loss: every over-retention bank was refreshed."""
        return all(b.refreshed for b in self.banks if b.needs_refresh)

    @property
    def pulse_exceeds_retention(self) -> bool:
        """Some bank's refresh pulse outlasts its retention interval —
        refresh on that bank can never hide under compute (it stalls
        every interval by construction; benchmarks surface a warning)."""
        return any(b.pulse_exceeds_retention for b in self.banks)


@dataclasses.dataclass
class ReplayCore:
    """The timing-model-independent result of walking a trace: allocator
    state (placements, occupancy integrals), traffic energies, and the
    per-op per-bank word tables both stall models consume.

    Produced by :func:`replay_core`; finished into a
    :class:`ControllerReport` either by :func:`replay` (additive stalls)
    or by the event-interleaved engine in ``repro.sim.timeline``.
    """
    cfg: ed.EDRAMConfig
    geom: BankGeometry
    sched: RefreshScheduler
    alloc: Allocator
    refresh_policy: str
    alloc_policy: str
    temp_c: float
    duration_s: float
    freq_hz: float
    read_j: float
    write_j: float
    offchip_j: float
    offchip_bits: float
    op_read_words: dict            # op name -> {bank index: words}
    op_write_words: dict
    restore_j: float = 0.0         # read-triggered restore share of read_j
    # vector-backend attachment (repro.memory.vector.VectorState): sparse
    # per-(op, bank) word arrays the vectorized closed-loop walk consumes
    # directly; None when the reference walk built this core
    vector: object = None
    # hybrid SRAM+eDRAM replays only (empty on single-tier cores): the
    # TierSpecs, one RefreshScheduler per tier (SRAM tiers get a "none"
    # scheduler at infinite retention), and per-tier traffic energies
    # whose folds ARE read_j/write_j/restore_j above (exact tier-sum)
    tiers: tuple = ()
    scheds: tuple = ()
    tier_read_j: tuple = ()
    tier_write_j: tuple = ()
    tier_restore_j: tuple = ()

    def sched_for(self, bank_index: int) -> RefreshScheduler:
        """The refresh scheduler owning global bank ``bank_index`` (the
        single shared one on classic cores)."""
        if not self.scheds:
            return self.sched
        return self.scheds[self.alloc.tier_of_bank(bank_index)]


def replay_core(events: Sequence[TraceEvent], cfg: ed.EDRAMConfig, *,
                temp_c: float, duration_s: float,
                refresh_policy: str = "selective",
                alloc_policy: str = "pingpong",
                freq_hz: float = 500e6,
                sample_scale: float = 1.0,
                refresh_guard: float = 1.0,
                retention_s: Optional[float] = None,
                granularity: str = "bank",
                reads_restore: bool = False,
                recorder=None,
                backend: str = "python",
                tiers=None) -> ReplayCore:
    """Walk ``events`` through allocator placement and traffic-energy
    accounting; returns the :class:`ReplayCore` a stall model finishes.

    ``sample_scale`` is the mini-batch size (see module docstring).
    Events tagged ``buffered`` are whole-iteration buffers (the FR arm's
    activation stash): they are placed at full batch size — they cannot
    be streamed sample-by-sample — and their residency counts unscaled
    against retention.  ``retention_s`` overrides the
    temperature-derived retention floor — pass ``math.inf`` to replay an
    SRAM tier that never refreshes.  ``granularity`` sets the refresh
    pulse unit (``"bank"`` | ``"row"`` — see
    :class:`~repro.memory.refresh.RefreshScheduler`).

    ``reads_restore=True`` models Kelle-style read-triggered restore
    (the substrate of the ``repro.serve`` KV policies): an eDRAM read is
    destructive, so writing the sensed value back costs the refresh
    restore phase (``cfg.refresh_restore_pj`` per bit, charged into
    ``read_j`` and split out as ``restore_j``) and resets the row's
    decay clock (:meth:`Allocator.touch`) — a bank whose every entry is
    re-read within retention then never needs a refresh pulse under the
    ``selective`` policy.  ``evict`` events release words like ``free``
    but record the tensor in ``evicted_tensors`` (dropped before its
    last reader).

    ``recorder`` is an optional :class:`repro.obs.SpanRecorder`: the
    walk then samples per-bank occupancy counters at every
    allocate/free, records one ``spill`` span per off-chip transfer, and
    a cumulative ``traffic_j`` counter at each energy-charging event.
    Observation only — placement, energies, and every counter the
    report reads are bit-identical with or without it.

    ``backend`` selects the replay engine (``REPLAY_BACKENDS``):
    ``"python"`` is this scalar walk; ``"vector"`` delegates to the
    numpy interval engine (``repro.memory.vector``), which returns a
    bit-identical core — a recorder or a tiered memory system downgrades
    it back to the reference walk (see :func:`resolve_backend`).

    ``tiers`` switches on the hybrid SRAM+eDRAM memory model: a sequence
    of :class:`~repro.memory.tiers.TierSpec` replaces the homogeneous
    bank array with a :class:`~repro.memory.tiers.MemorySystem`
    (``alloc_policy`` then names a *tier* policy, e.g.
    ``"lifetime_tiered"``), each tier gets its own refresh scheduler
    (SRAM tiers never refresh) and its own access energies, and the core
    carries per-tier traffic splits whose folds are the report totals.
    """
    if resolve_backend(backend, recorder, tiers=tiers) == "vector":
        from repro.memory import vector as vec
        return vec.replay_core_vector(
            events, cfg, temp_c=temp_c, duration_s=duration_s,
            refresh_policy=refresh_policy, alloc_policy=alloc_policy,
            freq_hz=freq_hz, sample_scale=sample_scale,
            refresh_guard=refresh_guard, retention_s=retention_s,
            granularity=granularity, reads_restore=reads_restore)
    tier_specs = tuple(tiers) if tiers else ()
    if tier_specs:
        scheds = []
        for t in tier_specs:
            if t.cell == "sram":
                scheds.append(RefreshScheduler(
                    "none", temp_c, guard=refresh_guard,
                    retention_s=math.inf, granularity=granularity))
            else:
                scheds.append(RefreshScheduler(
                    refresh_policy, temp_c, guard=refresh_guard,
                    retention_s=(t.retention_s if t.retention_s is not None
                                 else retention_s),
                    granularity=granularity))
        edram_scheds = [s for t, s in zip(tier_specs, scheds)
                        if t.cell == "edram"]
        # the report-level retention/interval are the decaying (eDRAM)
        # tier's — the quantity the refresh verdict is about
        sched = edram_scheds[0] if edram_scheds else scheds[0]
        alloc = MemorySystem(tier_specs,
                             [s.retention_s for s in scheds],
                             policy=alloc_policy)
        # nominal geometry: only word_bits matters to this walk (words_for
        # in the prepasses and _touch); per-bank capacities live on each
        # BankState's own geometry
        geom = BankGeometry(
            word_bits=tier_specs[0].word_bits,
            words_per_bank=max(t.geometry().words_per_bank
                               for t in tier_specs),
            n_banks=len(alloc.banks),
            rows_per_bank=max(t.rows_per_bank for t in tier_specs))
    else:
        scheds = None
        geom = BankGeometry.from_edram(cfg)
        sched = RefreshScheduler(refresh_policy, temp_c,
                                 guard=refresh_guard,
                                 retention_s=retention_s,
                                 granularity=granularity)
        alloc = Allocator(geom, policy=alloc_policy,
                          retention_s=sched.retention_s)
    if recorder is not None:
        def _sample_occupancy(bank, now):
            recorder.counter("occupied_words", now, bank.used_words,
                             bank=bank.index)
        for b in alloc.banks:
            b.on_occupancy = _sample_occupancy
            _sample_occupancy(b, 0.0)

    # prepass: expected residency window per tensor (write → free), at
    # trace time — the lifetime-aware allocator colors banks with it.  A
    # tensor can be resident more than once (freed in forward, re-written
    # in backward); each free closes one window, and the classification
    # conservatively uses the tensor's longest single residency.
    first_seen: dict[str, float] = {}
    window: dict[str, float] = {}
    for ev in events:
        if ev.kind in ("alloc", "write"):
            first_seen.setdefault(ev.tensor, ev.time)
        elif ev.kind in ("free", "evict") and ev.tensor in first_seen:
            w = ev.time - first_seen.pop(ev.tensor)
            window[ev.tensor] = max(window.get(ev.tensor, 0.0), w)
    for t, t0 in first_seen.items():     # never freed ⇒ lives to trace end
        window[t] = max(window.get(t, 0.0), duration_s - t0)

    # prepass 2: peak of the streamed (non-buffered) working set in words.
    # Whole-iteration buffers are lowest priority — they may only take
    # space the dataflow's live tensors will never need, otherwise they
    # spill (one store + one load) instead of evicting the stream later.
    live_w: dict[str, int] = {}
    transient_peak_w = cur_w = 0
    for ev in events:
        if ev.buffered:
            continue
        if ev.kind in ("alloc", "write"):
            if ev.tensor not in live_w:
                w = geom.words_for(ev.bits / sample_scale)
                live_w[ev.tensor] = w
                cur_w += w
                transient_peak_w = max(transient_peak_w, cur_w)
        elif ev.kind in ("free", "evict"):
            cur_w -= live_w.pop(ev.tensor, 0)

    read_j = write_j = offchip_j = restore_j = 0.0
    # tiered mode accumulates traffic energy per tier (each tier has its
    # own pJ/bit); the totals are the folds of these lists, so per-tier
    # energies sum to the report totals *exactly*
    t_read = [0.0] * len(tier_specs)
    t_write = [0.0] * len(tier_specs)
    t_restore = [0.0] * len(tier_specs)
    transient_now_w = 0               # on-chip streamed words right now
    offchip_bits = 0.0

    def _traffic_total() -> float:
        if tier_specs:
            return sum(t_read) + sum(t_write) + offchip_j
        return read_j + write_j + offchip_j
    # per-op, per-bank words touched (the conflict model's unit)
    op_read_words: dict[str, dict[int, int]] = {}
    op_write_words: dict[str, dict[int, int]] = {}

    def _touch(table, op, placement, bits):
        # distribute the op's traffic words over the tensor's bank spans
        words = geom.words_for(bits)
        span_total = max(1, sum(w for _, w in placement.spans))
        per = table.setdefault(op, {})
        for bank_idx, span_words in placement.spans:
            per[bank_idx] = per.get(bank_idx, 0) + max(
                1, round(words * span_words / span_total))

    for ev in events:
        if ev.kind not in EVENT_KINDS:
            raise ValueError(f"unknown trace event kind {ev.kind!r}")
        # whole-iteration buffers hold every sample's value at once
        scale = 1.0 if ev.buffered else 1.0 / sample_scale
        if ev.kind in ("alloc", "write"):
            p = alloc.location(ev.tensor)
            if p is not None:
                alloc.rewrite(ev.tensor, ev.time)
            else:
                w = window.get(ev.tensor)
                reserve = (max(0, transient_peak_w - transient_now_w)
                           if ev.buffered else 0)
                p = alloc.place(ev.tensor, ev.bits * scale, ev.time,
                                expected_lifetime_s=(
                                    None if w is None else w * scale),
                                lifetime_scale=scale,
                                reserve_words=reserve)
                if not ev.buffered and not p.offchip:
                    transient_now_w += sum(sw for _, sw in p.spans)
            if ev.kind == "write":
                if p.offchip:
                    offchip_j += ev.bits * cfg.dram_pj_per_bit * 1e-12
                    offchip_bits += ev.bits
                    if recorder is not None:
                        recorder.span("spill", ev.tensor, ev.time, ev.time,
                                      op=ev.op, io="write", bits=ev.bits)
                else:
                    if tier_specs:
                        k = alloc.tier_of_bank(p.spans[0][0])
                        t_write[k] += ev.bits \
                            * tier_specs[k].write_pj_per_bit * 1e-12
                    else:
                        write_j += ev.bits * cfg.write_pj_per_bit * 1e-12
                    for b_idx, _ in p.spans:
                        alloc.banks[b_idx].write_bits += \
                            ev.bits / max(1, len(p.spans))
                    _touch(op_write_words, ev.op, p, ev.bits)
                if recorder is not None:
                    recorder.counter("traffic_j", ev.time,
                                     _traffic_total())
        elif ev.kind == "read":
            p = alloc.location(ev.tensor)
            if p is None or p.offchip:
                offchip_j += ev.bits * cfg.dram_pj_per_bit * 1e-12
                offchip_bits += ev.bits
                if recorder is not None:
                    recorder.span("spill", ev.tensor, ev.time, ev.time,
                                  op=ev.op, io="read", bits=ev.bits)
            else:
                if tier_specs:
                    k = alloc.tier_of_bank(p.spans[0][0])
                    pj = tier_specs[k].read_pj_per_bit
                    if reads_restore:
                        # SRAM reads are non-destructive: the tier's
                        # restore phase is 0 pJ, so only decaying tiers
                        # pay the write-back (touch still resets clocks)
                        pj += tier_specs[k].refresh_restore_pj_per_bit
                        t_restore[k] += ev.bits \
                            * tier_specs[k].refresh_restore_pj_per_bit \
                            * 1e-12
                        alloc.touch(ev.tensor, ev.time)
                    t_read[k] += ev.bits * pj * 1e-12
                else:
                    pj = cfg.read_pj_per_bit
                    if reads_restore:
                        # destructive read + write-back: the restore
                        # phase of a refresh pulse rides every read, and
                        # the row's decay clock restarts (touch) — this
                        # is what lets ``selective`` skip refreshing
                        # well-read banks.
                        pj += cfg.refresh_restore_pj
                        restore_j += ev.bits * cfg.refresh_restore_pj \
                            * 1e-12
                        alloc.touch(ev.tensor, ev.time)
                    read_j += ev.bits * pj * 1e-12
                for b_idx, _ in p.spans:
                    alloc.banks[b_idx].read_bits += \
                        ev.bits / max(1, len(p.spans))
                _touch(op_read_words, ev.op, p, ev.bits)
            if recorder is not None:
                recorder.counter("traffic_j", ev.time,
                                 _traffic_total())
        elif ev.kind in ("free", "evict"):
            p = alloc.location(ev.tensor)
            if not ev.buffered and p is not None and not p.offchip:
                transient_now_w -= sum(sw for _, sw in p.spans)
            if ev.kind == "evict":
                alloc.evict(ev.tensor, ev.time)
            else:
                alloc.free(ev.tensor, ev.time)

    for b in alloc.banks:
        b.finalize(duration_s)

    if tier_specs:
        # totals ARE the folds of the per-tier splits (exact tier-sum)
        read_j = sum(t_read)
        write_j = sum(t_write)
        restore_j = sum(t_restore)

    return ReplayCore(
        cfg=cfg, geom=geom, sched=sched, alloc=alloc,
        refresh_policy=refresh_policy, alloc_policy=alloc_policy,
        temp_c=temp_c, duration_s=duration_s, freq_hz=freq_hz,
        read_j=read_j, write_j=write_j, offchip_j=offchip_j,
        offchip_bits=offchip_bits,
        op_read_words=op_read_words, op_write_words=op_write_words,
        restore_j=restore_j,
        tiers=tier_specs,
        scheds=tuple(scheds) if scheds else (),
        tier_read_j=tuple(t_read), tier_write_j=tuple(t_write),
        tier_restore_j=tuple(t_restore))


def account_refresh(core: ReplayCore, duration_s: float, *,
                    placements: Optional[dict] = None,
                    pulse_stats: Optional[dict] = None) -> list:
    """Run the refresh energy/stall accounting for a finished core —
    one scheduler over the whole array on classic cores, one scheduler
    per tier (with that tier's refresh energies) on hybrid cores.  The
    returned decisions are in global bank order either way, ready for
    :func:`build_report`'s ``zip`` against ``core.alloc.banks``."""
    if not core.tiers:
        return core.sched.account(core.alloc.banks, duration_s,
                                  core.freq_hz,
                                  core.cfg.refresh_read_pj,
                                  core.cfg.refresh_restore_pj,
                                  placements=placements,
                                  pulse_stats=pulse_stats)
    decisions: list = []
    for k, (tier, sched) in enumerate(zip(core.tiers, core.scheds)):
        decisions.extend(sched.account(
            core.alloc.tier_banks(k), duration_s, core.freq_hz,
            tier.refresh_read_pj_per_bit, tier.refresh_restore_pj_per_bit,
            placements=placements, pulse_stats=pulse_stats))
    return decisions


def _tier_summaries(core: ReplayCore, banks: Sequence,
                    decisions: Sequence) -> tuple:
    """JSON-safe per-tier summary dicts for ``ControllerReport.tiers``
    (``banks`` are the finished :class:`BankReport` rows)."""
    out = []
    for k, tier in enumerate(core.tiers):
        lo = core.alloc.offsets[k]
        hi = lo + tier.n_banks
        tb, td = banks[lo:hi], decisions[lo:hi]
        retention = core.scheds[k].retention_s
        refresh_read_j = sum(d.refresh_read_j for d in td)
        refresh_restore_j = sum(d.refresh_restore_j for d in td)
        out.append({
            "name": tier.name, "cell": tier.cell,
            "n_banks": tier.n_banks, "bank_start": lo,
            "capacity_bits": tier.capacity_bits,
            "retention_s": retention if math.isfinite(retention) else None,
            "read_j": core.tier_read_j[k],
            "write_j": core.tier_write_j[k],
            "restore_j": core.tier_restore_j[k],
            "refresh_read_j": refresh_read_j,
            "refresh_restore_j": refresh_restore_j,
            "refresh_j": refresh_read_j + refresh_restore_j,
            "refresh_count": sum(b.refresh_count for b in tb),
            "refresh_stall_s": sum(d.stall_s for d in td),
            "refresh_hidden_j": sum(d.refresh_hidden_j for d in td),
            "read_bits": sum(b.read_bits for b in tb),
            "write_bits": sum(b.write_bits for b in tb),
            "peak_words": sum(b.peak_words for b in tb),
            "leakage_mw": tier.leakage_mw,
        })
    return tuple(out)


def build_report(core: ReplayCore, decisions: Sequence, *,
                 conflict_stall_s: float, timing: str,
                 timeline: Optional[dict] = None) -> ControllerReport:
    """Assemble the :class:`ControllerReport` from a finished replay core
    and the refresh scheduler's per-bank decisions.  Shared by the
    additive model (:func:`replay`) and the timeline engine
    (``repro.sim.timeline``)."""
    if core.tiers:
        # fold per tier first, then fold the tier sums — the report
        # totals then equal the sum of the per-tier summary fields
        # exactly (the tier-sum invariant the property suite pins)
        slices = [(core.alloc.offsets[k],
                   core.alloc.offsets[k] + t.n_banks)
                  for k, t in enumerate(core.tiers)]
        refresh_read_j = sum(sum(d.refresh_read_j
                                 for d in decisions[lo:hi])
                             for lo, hi in slices)
        refresh_restore_j = sum(sum(d.refresh_restore_j
                                    for d in decisions[lo:hi])
                                for lo, hi in slices)
        refresh_stall = sum(sum(d.stall_s for d in decisions[lo:hi])
                            for lo, hi in slices)
        refresh_hidden_j = sum(sum(d.refresh_hidden_j
                                   for d in decisions[lo:hi])
                               for lo, hi in slices)
    else:
        refresh_read_j = sum(d.refresh_read_j for d in decisions)
        refresh_restore_j = sum(d.refresh_restore_j for d in decisions)
        refresh_stall = sum(d.stall_s for d in decisions)
        refresh_hidden_j = sum(d.refresh_hidden_j for d in decisions)
    rows_refreshed = sum(d.rows_refreshed for d in decisions)
    rows_hidden = (sum(d.hidden_count for d in decisions)
                   if core.sched.granularity == "row" else 0)

    banks = tuple(
        BankReport(
            index=b.index, read_bits=b.read_bits, write_bits=b.write_bits,
            refresh_bits=b.refresh_bits, refresh_count=b.refresh_count,
            refresh_j=d.refresh_j, stall_s=b.stall_s,
            peak_words=b.peak_words,
            peak_occupancy=b.peak_words / b.geometry.words_per_bank,
            max_resident_lifetime_s=b.max_resident_s,
            needs_refresh=d.needs_refresh, refreshed=d.refreshed,
            busy_s=b.busy_s, refresh_hidden=d.hidden_count,
            pulse_exceeds_retention=d.pulse_exceeds_retention,
            rows_refreshed=d.rows_refreshed)
        for b, d in zip(core.alloc.banks, decisions))

    tier_rows = (_tier_summaries(core, banks, tuple(decisions))
                 if core.tiers else ())

    return ControllerReport(
        refresh_policy=core.refresh_policy, alloc_policy=core.alloc_policy,
        temp_c=core.temp_c, duration_s=core.duration_s, banks=banks,
        read_j=core.read_j, write_j=core.write_j,
        refresh_j=refresh_read_j + refresh_restore_j,
        offchip_j=core.offchip_j,
        stall_s=conflict_stall_s + refresh_stall,
        spill_bits=core.alloc.spill_bits, offchip_bits=core.offchip_bits,
        spilled_tensors=tuple(core.alloc.spilled),
        restore_j=core.restore_j,
        evicted_tensors=tuple(core.alloc.evicted),
        refresh_read_j=refresh_read_j,
        refresh_restore_j=refresh_restore_j,
        retention_s=core.sched.retention_s,
        interval_s=core.sched.interval_s,
        timing=timing, conflict_stall_s=conflict_stall_s,
        refresh_stall_s=refresh_stall, refresh_hidden_j=refresh_hidden_j,
        timeline=timeline,
        granularity=core.sched.granularity,
        rows_refreshed=rows_refreshed,
        row_hidden_frac=(rows_hidden / rows_refreshed
                         if rows_refreshed else 0.0),
        tiers=tier_rows)


def replay(events: Sequence[TraceEvent], cfg: ed.EDRAMConfig, *,
           temp_c: float, duration_s: float,
           refresh_policy: str = "selective",
           alloc_policy: str = "pingpong",
           freq_hz: float = 500e6,
           sample_scale: float = 1.0,
           op_durations: Optional[dict] = None,
           refresh_guard: float = 1.0,
           retention_s: Optional[float] = None,
           granularity: str = "bank",
           reads_restore: bool = False,
           recorder=None,
           backend: str = "python",
           tiers=None) -> ControllerReport:
    """Replay ``events`` through the bank-level controller with the
    **additive** stall model (the cross-validation baseline; the
    closed-loop model lives in ``repro.sim.timeline``).

    Args:
        events: the schedule's :class:`TraceEvent` stream (bits per
            event; times in seconds on the unconstrained op timeline).
        cfg: bank geometry + access energies (pJ/bit fields).
        temp_c: die temperature in °C — sets the retention floor.
        duration_s: schedule length in seconds.
        refresh_policy: ``always | none | selective``.
        alloc_policy: ``pingpong | first_fit | lifetime``.
        freq_hz: port clock; each bank port moves one word per cycle.
        sample_scale: the mini-batch size (see module docstring) —
            streamed tensors are placed at ``bits/sample_scale``.
        op_durations: op name → seconds; enables the bank-conflict
            model — an op whose per-bank port time exceeds its compute
            time stalls the array for the difference, and every refresh
            pulse serializes against the ports (no hiding).
        refresh_guard: divides the refresh interval (guard-banding).
        retention_s: overrides the temperature-derived retention floor —
            pass ``math.inf`` to replay an SRAM tier that never
            refreshes.
        granularity: refresh pulse unit (``"bank"`` | ``"row"``).  The
            additive stall total is granularity-invariant (one tick's
            row pulses serialize to the same port time as the bank
            pulse); only the ``pulse_exceeds_retention`` flag and the
            row counters move.
        reads_restore: charge the refresh restore phase on every on-chip
            read and reset the touched rows' decay clocks (see
            :func:`replay_core` — the ``repro.serve`` KV-policy
            substrate).
        recorder: optional ``repro.obs.SpanRecorder`` — records the
            replay-core observables (occupancy counters, spill spans);
            the additive model places no pulses, so the trace carries no
            refresh spans and cannot be reconciled (use the timeline
            model for that).
        backend: replay engine (``REPLAY_BACKENDS``) — ``"python"``
            (the scalar reference walk) or ``"vector"`` (the numpy
            interval engine, bit-identical reports); a recorder or a
            tiered memory system downgrades ``"vector"`` (see
            :func:`resolve_backend`).
        tiers: optional :class:`~repro.memory.tiers.TierSpec` sequence —
            replay against a hybrid SRAM+eDRAM
            :class:`~repro.memory.tiers.MemorySystem` (see
            :func:`replay_core`); the report then carries per-tier
            summaries in ``ControllerReport.tiers``.

    Returns:
        A :class:`ControllerReport` (energies in J, stalls in s) with
        ``timing="additive"``.
    """
    core = replay_core(
        events, cfg, temp_c=temp_c, duration_s=duration_s,
        refresh_policy=refresh_policy, alloc_policy=alloc_policy,
        freq_hz=freq_hz, sample_scale=sample_scale,
        refresh_guard=refresh_guard, retention_s=retention_s,
        granularity=granularity, reads_restore=reads_restore,
        recorder=recorder, backend=backend, tiers=tiers)
    if recorder is not None:
        recorder.meta.update(timing="additive", schedule_s=duration_s,
                             granularity=granularity, temp_c=temp_c,
                             refresh_policy=refresh_policy,
                             freq_hz=freq_hz)

    # bank-conflict stalls: each bank moves one word/cycle/port; an op is
    # stalled by its most-contended bank beyond its own compute time
    stall_s = 0.0
    if op_durations:
        for table in (core.op_read_words, core.op_write_words):
            for op, per_bank in table.items():
                if not per_bank:
                    continue
                # zero-duration ops are elementwise adds/copies fused into
                # the producing MAC op's pipeline (Fig 12) — their operands
                # ride the producer's port slots, no extra stall
                dur = op_durations.get(op, 0.0)
                if dur <= 0.0:
                    continue
                worst = max(per_bank.values())
                port_s = port_service_s(worst, freq_hz)
                extra = max(0.0, port_s - dur)
                stall_s += extra
                argmax = max(per_bank, key=per_bank.get)
                core.alloc.banks[argmax].stall_s += extra

    # residencies were scaled per tensor at the bank level, so account()
    # compares them against retention directly (lifetime_scale=1)
    decisions = account_refresh(core, duration_s)
    return build_report(core, decisions, conflict_stall_s=stall_s,
                        timing="additive")
