"""Per-bank refresh scheduling (CAMEL §V-D, Figs 22/23).

Policies:

``always``
    Conventional DRAM discipline: every bank holding data is refreshed
    each retention interval, whether its contents need it or not.
``none``
    No refresh at all — only safe when every resident tensor's lifetime is
    under retention (the pure co-design operating point, Fig 23).
``selective``
    The CAMEL controller: a bank is refreshed only while its longest
    resident lifetime exceeds the retention floor; banks whose tensors all
    die young are skipped.  Energy falls between ``none`` and ``always``
    and no over-retention bank is ever left unrefreshed.

The interval is temperature-adaptive — ``retention_s(temp_c) / guard`` —
so the same schedule tightens automatically as the die heats up (Fig 22).
Refresh energy integrates each refreshed bank's occupancy over time
(∫occ·dt / interval × pJ/bit): a bank half-full for half the iteration
costs a quarter of a full bank, which the scalar ``edram_energy`` model
(peak-bits × intervals) can only upper-bound.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import edram as ed
from repro.memory.banks import BankState, port_service_s

REFRESH_POLICIES = ("always", "none", "selective")


@dataclasses.dataclass(frozen=True)
class RefreshDecision:
    bank: int
    refreshed: bool
    needs_refresh: bool        # max resident lifetime ≥ retention
    refresh_j: float
    refresh_count: int
    stall_s: float


class RefreshScheduler:
    """Decides which banks to refresh and accounts energy + port stalls."""

    def __init__(self, policy: str, temp_c: float, guard: float = 1.0,
                 interval_s: float | None = None):
        if policy not in REFRESH_POLICIES:
            raise ValueError(f"unknown refresh policy {policy!r}; "
                             f"choose from {REFRESH_POLICIES}")
        self.policy = policy
        self.temp_c = temp_c
        self.retention_s = ed.retention_s(temp_c)
        self.interval_s = (interval_s if interval_s is not None
                           else ed.refresh_interval_s(temp_c, guard))

    def needs_refresh(self, bank: BankState) -> bool:
        """The per-bank co-design criterion (eq 10 at bank granularity)."""
        return bank.max_resident_s >= self.retention_s

    def account(self, banks: Sequence[BankState], duration_s: float,
                freq_hz: float, refresh_pj_per_bit: float,
                lifetime_scale: float = 1.0) -> list[RefreshDecision]:
        """Charge refresh energy/stalls for one iteration of ``duration_s``.

        ``lifetime_scale`` rescales observed residency durations before the
        retention comparison (the weight-stationary dataflow streams the
        batch sample-by-sample, so a trace recorded at whole-batch op times
        represents per-sample lifetimes 1/batch as long — hwmodel passes
        1/batch, mirroring its scalar path).

        Mutates each bank's ``refresh_count``/``refresh_bits``/``stall_s``
        counters and returns per-bank decisions.
        """
        ticks = math.ceil(duration_s / self.interval_s) \
            if duration_s > 0 else 0
        out = []
        for b in banks:
            needs = (b.max_resident_s * lifetime_scale) >= self.retention_s
            held_data = b.occ_bit_s > 0
            refreshed = held_data and (
                self.policy == "always"
                or (self.policy == "selective" and needs))
            refresh_j = 0.0
            count = 0
            stall = 0.0
            if refreshed:
                # ∫occ·dt / interval — fractional intervals included, so a
                # short iteration still pays its pro-rata share
                bit_intervals = b.occ_bit_s / self.interval_s
                refresh_j = bit_intervals * refresh_pj_per_bit * 1e-12
                count = ticks
                # each refresh pulse occupies the ports for its resident
                # words (read + restore through the same word line)
                words = b.peak_words
                stall = count * port_service_s(words, freq_hz)
                b.refresh_count += count
                b.refresh_bits += bit_intervals
                b.stall_s += stall
            out.append(RefreshDecision(bank=b.index, refreshed=refreshed,
                                       needs_refresh=needs,
                                       refresh_j=refresh_j,
                                       refresh_count=count, stall_s=stall))
        return out
