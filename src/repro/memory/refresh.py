"""Per-bank refresh scheduling (CAMEL §V-D, Figs 22/23).

Policies:

``always``
    Conventional DRAM discipline: every bank holding data is refreshed
    each retention interval, whether its contents need it or not.
``none``
    No refresh at all — only safe when every resident tensor's lifetime is
    under retention (the pure co-design operating point, Fig 23).
``selective``
    The CAMEL controller: a bank is refreshed only while its longest
    resident lifetime exceeds the retention floor; banks whose tensors all
    die young are skipped.  Energy falls between ``none`` and ``always``
    and no over-retention bank is ever left unrefreshed.

Orthogonal to the policy, the *granularity* sets the pulse unit: the
conventional one-pulse-per-bank discipline (``"bank"``), or the paper
controller's row-granular refresh (``"row"`` — one pulse per occupied
wordline, so compute interleaves with refresh at row boundaries and a
near-full bank can still hide its refresh row by row).

The interval is temperature-adaptive — ``retention_s(temp_c) / guard`` —
so the same schedule tightens automatically as the die heats up (Fig 22).
Refresh energy integrates each refreshed bank's occupancy over time
(∫occ·dt / interval × pJ/bit): a bank half-full for half the iteration
costs a quarter of a full bank, which the scalar ``edram_energy`` model
(peak-bits × intervals) can only upper-bound.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Sequence

from repro.core import edram as ed
from repro.memory.banks import BankState, port_service_s

REFRESH_POLICIES = ("always", "none", "selective")

# pulse granularity: "bank" refreshes a bank's whole occupancy in one
# pulse per retention tick; "row" emits one pulse per occupied wordline
# (words_per_row words each), placed independently — compute interleaves
# with refresh at row boundaries, as in the paper's controller
REFRESH_GRANULARITIES = ("bank", "row")


@dataclasses.dataclass(frozen=True)
class RefreshDecision:
    bank: int
    refreshed: bool
    needs_refresh: bool        # max resident lifetime ≥ retention
    refresh_j: float           # read + restore total (J)
    refresh_count: int
    stall_s: float             # port time not hidden under compute (s)
    refresh_read_j: float = 0.0     # sense phase (J)
    refresh_restore_j: float = 0.0  # write-back phase (J)
    # timeline model only: pulses that landed in bank-idle windows, and
    # the share of refresh_j they carry (energy still paid, time hidden)
    hidden_count: int = 0
    refresh_hidden_j: float = 0.0
    # the can-never-hide case (ROADMAP): this bank's pulse needs more
    # continuous port time than one retention interval provides, so no
    # idle window can ever fit it — every pulse stalls, by construction.
    # Granularity-aware: under row granularity the pulse unit is one
    # row's words, so a near-full bank whose *row* pulse fits the
    # interval is not flagged even when its whole-bank pulse would be
    pulse_exceeds_retention: bool = False
    # row-granular pulses emitted for this bank (0 under bank
    # granularity); hidden_count counts the same unit
    rows_refreshed: int = 0


class PulsePlacement(NamedTuple):
    """One refresh pulse placed on the event-interleaved timeline.

    ``deadline_s`` is the end of the pulse's retention interval; the
    scheduler tries to start the pulse at ``start_s`` inside a bank-idle
    window before that deadline.  ``hidden`` pulses cost energy but no
    time; a pulse with no idle window preempts the ports at its deadline
    and charges ``stall_s`` seconds of serialization.

    Under row granularity one placement is emitted per *hidden* row per
    tick: ``row`` is the 0-based wordline index and ``words`` the words
    that row's pulse moves (``words_per_row``, except a partial last
    row).  Rows refresh strictly in row order; once a row finds no gap,
    every later row of that tick preempts with it — that run is emitted
    as a single placement with ``rows`` > 1 whose ``words``/``stall_s``
    are the run's totals.  Under bank granularity ``row`` stays 0,
    ``rows`` 1, and ``words`` is the bank's whole ``peak_words``.
    """
    bank: int
    index: int                 # 1-based retention tick
    deadline_s: float
    start_s: float
    hidden: bool
    stall_s: float
    row: int = 0
    words: int = 0
    rows: int = 1              # pulse multiplicity (a preempting run)


def placement_interval(p: PulsePlacement,
                       freq_hz: float) -> tuple[float, float]:
    """The port interval a placement occupies on the timeline, in
    seconds — the flight recorder's span for the pulse.

    A *hidden* pulse sits inside its idle window/gap:
    ``[start_s, start_s + port_service_s(words, freq_hz))`` — the exact
    width :meth:`RefreshScheduler.place_pulses` packed with, so recorded
    spans can never overlap a busy interval or each other.  A preempting
    pulse (or aggregated run of row pulses) serializes at its deadline:
    ``[start_s, start_s + stall_s)``.
    """
    if p.hidden:
        return p.start_s, p.start_s + port_service_s(p.words, freq_hz)
    return p.start_s, p.start_s + p.stall_s


class RefreshScheduler:
    """Decides which banks to refresh and accounts energy + port stalls.

    ``retention_s`` overrides the temperature-derived retention floor —
    pass ``math.inf`` to model a static technology (the SRAM baseline's
    controller replay) that never needs refresh.

    ``granularity`` selects the pulse unit (``REFRESH_GRANULARITIES``):
    ``"bank"`` (default) refreshes a bank's whole occupancy in one pulse
    per retention tick; ``"row"`` emits an independent pulse per occupied
    wordline, so refresh interleaves with compute at row boundaries.
    Refresh *energy* is granularity-invariant — it integrates occupancy
    over time (∫occ·dt), which placement does not touch.
    """

    def __init__(self, policy: str, temp_c: float, guard: float = 1.0,
                 interval_s: float | None = None,
                 retention_s: float | None = None,
                 granularity: str = "bank"):
        if policy not in REFRESH_POLICIES:
            raise ValueError(f"unknown refresh policy {policy!r}; "
                             f"choose from {REFRESH_POLICIES}")
        if granularity not in REFRESH_GRANULARITIES:
            raise ValueError(f"unknown refresh granularity {granularity!r};"
                             f" choose from {REFRESH_GRANULARITIES}")
        self.policy = policy
        self.granularity = granularity
        self.temp_c = temp_c
        self.retention_s = (retention_s if retention_s is not None
                            else ed.retention_s(temp_c))
        # an overridden retention floor implies the interval too (an SRAM
        # replay's inf retention must not report a finite eDRAM interval)
        if interval_s is not None:
            self.interval_s = interval_s
        elif retention_s is not None:
            self.interval_s = retention_s / max(guard, 1e-9)
        else:
            self.interval_s = ed.refresh_interval_s(temp_c, guard)

    def needs_refresh(self, bank: BankState) -> bool:
        """The per-bank co-design criterion (eq 10 at bank granularity)."""
        return bank.max_resident_s >= self.retention_s

    def would_refresh(self, bank: BankState,
                      lifetime_scale: float = 1.0) -> bool:
        """Whether the policy refreshes ``bank`` at all this iteration:
        the bank must hold data, and under ``selective`` its longest
        resident data lifetime must reach the retention floor."""
        needs = (bank.max_resident_s * lifetime_scale) >= self.retention_s
        held_data = bank.occ_bit_s > 0
        return held_data and (self.policy == "always"
                              or (self.policy == "selective" and needs))

    def pulse_chunks(self, bank: BankState) -> list[int]:
        """Word counts of the pulses one retention tick emits for
        ``bank``: ``[peak_words]`` under bank granularity; one entry per
        occupied wordline (``words_per_row`` each, partial last row)
        under row granularity."""
        if bank.peak_words <= 0:
            return []
        if self.granularity == "bank":
            return [bank.peak_words]
        wpr = bank.geometry.words_per_row
        rows = bank.geometry.rows_for(bank.peak_words)
        chunks = [wpr] * rows
        chunks[-1] = bank.peak_words - wpr * (rows - 1)
        return chunks

    def place_pulses(self, bank: BankState, duration_s: float,
                     freq_hz: float) -> list[PulsePlacement]:
        """Deadline-driven pulse placement for the timeline model.

        Bank granularity: one pulse per retention tick (``interval_s``)
        over ``duration_s`` seconds of timeline.  Each pulse needs the
        bank's ports for ``port_service_s(peak_words)`` seconds (read the
        droop + restore through the same word line); the scheduler looks
        for a bank-idle window of that length inside the pulse's own
        retention interval ``[(k-1)·I, min(k·I, duration_s)]``.  A window
        found ⇒ the pulse is *hidden* under compute (energy charged, zero
        stall); no window ⇒ the pulse preempts at its deadline and
        charges its full port time as ``stall_s``.

        Row granularity: each tick emits one pulse per occupied wordline
        (``port_service_s(words_per_row)`` each), packed front-to-back in
        row order into the tick's idle gaps (``BankState.idle_gaps``) —
        compute interleaves with refresh at row boundaries, placed pulses
        never overlap each other or a busy interval, and only the rows
        that find no gap preempt at the deadline and stall.  The row
        counter never skips ahead: once a row cannot be placed, the rest
        of the tick's rows preempt with it, returned as one aggregated
        :class:`PulsePlacement` (``rows`` = the run length).

        Pure query — mutates nothing; feed the result to :meth:`account`
        via ``placements`` to commit counters and energy.
        """
        if duration_s <= 0 or not math.isfinite(self.interval_s):
            return []
        chunks = self.pulse_chunks(bank)
        widths = [port_service_s(w, freq_hz) for w in chunks]
        ticks = math.ceil(duration_s / self.interval_s)
        out: list[PulsePlacement] = []
        for k in range(1, ticks + 1):
            lo = (k - 1) * self.interval_s
            deadline = min(k * self.interval_s, duration_s)
            if self.granularity == "bank":
                for words, pulse_s in zip(chunks, widths):
                    start = bank.idle_window(lo, deadline, pulse_s)
                    hidden = start is not None
                    out.append(PulsePlacement(
                        bank=bank.index, index=k, deadline_s=deadline,
                        start_s=start if hidden else deadline,
                        hidden=hidden,
                        stall_s=0.0 if hidden else pulse_s,
                        row=0, words=words))
                continue
            # row granularity: pack the tick's row pulses greedily into
            # the idle gaps, in row order (the controller's row counter)
            gaps = bank.idle_gaps(lo, deadline)
            gi, cursor = 0, (gaps[0][0] if gaps else deadline)
            r = 0
            while r < len(chunks):
                pulse_s = widths[r]
                start = None
                while gi < len(gaps):
                    if gaps[gi][1] - cursor >= pulse_s:
                        start = cursor
                        cursor += pulse_s
                        break
                    gi += 1
                    if gi < len(gaps):
                        cursor = gaps[gi][0]
                if start is not None:
                    out.append(PulsePlacement(
                        bank=bank.index, index=k, deadline_s=deadline,
                        start_s=start, hidden=True, stall_s=0.0,
                        row=r, words=chunks[r]))
                    r += 1
                    continue
                # gaps exhausted — this row and every later one preempt
                out.append(PulsePlacement(
                    bank=bank.index, index=k, deadline_s=deadline,
                    start_s=deadline, hidden=False,
                    stall_s=sum(widths[r:]), row=r,
                    words=sum(chunks[r:]), rows=len(chunks) - r))
                break
        return out

    def account(self, banks: Sequence[BankState], duration_s: float,
                freq_hz: float, refresh_read_pj_per_bit: float,
                refresh_restore_pj_per_bit: float,
                lifetime_scale: float = 1.0,
                placements: Optional[dict] = None,
                pulse_stats: Optional[dict] = None) -> list[RefreshDecision]:
        """Charge refresh energy/stalls for one iteration of ``duration_s``.

        Args:
            banks: the ``BankState`` objects the replay populated.
            duration_s: iteration length in **seconds** (the timeline
                makespan when the caller uses the timeline model).
            freq_hz: port clock — one word moves per cycle per port.
            refresh_read_pj_per_bit: sense-phase energy, **pJ/bit**.
            refresh_restore_pj_per_bit: write-back energy, **pJ/bit**.
            lifetime_scale: rescales observed residency durations before
                the retention comparison.  ``BankState`` already scales
                residencies per tensor (``_Residency.scale``), so callers
                that pre-scale pass the default 1.0.
            placements: optional ``{bank index: [PulsePlacement, ...]}``
                from :meth:`place_pulses` (the timeline model).  When
                given, a bank's stall is the sum of its *unhidden* pulse
                stalls instead of full per-pulse serialization, and the
                energy of hidden pulses is surfaced as
                ``refresh_hidden_j``.
            pulse_stats: vector-backend alternative to ``placements``:
                ``{bank index: (count, stall_s, hidden)}`` pre-reduced
                from ``repro.memory.vector.BankPulses`` (same left-fold
                sums the placement branch would compute).  Ignored when
                ``placements`` is given.

        Returns:
            One :class:`RefreshDecision` per bank (energy in **J**,
            stalls in **s**).  Refresh energy integrates occupancy over
            time (∫occ·dt / interval × pJ/bit) and is split into the
            sense/read and restore/write-back phases;
            ``RefreshDecision.refresh_j`` stays the total — and is
            granularity-invariant, since pulse placement never enters the
            integral.  A refreshed bank whose pulse unit (the whole
            occupancy under bank granularity, one row's words under row
            granularity) needs more port time than the retention interval
            provides is flagged ``pulse_exceeds_retention`` — it can
            never hide (note the pulse width scales with 1/``freq_hz``
            while the interval is wall-clock, so clocking down can trip
            this, and moving to row granularity can clear it).

        Mutates each bank's ``refresh_count`` / ``refresh_bits`` /
        ``refresh_hidden`` / ``stall_s`` counters.
        """
        ticks = math.ceil(duration_s / self.interval_s) \
            if duration_s > 0 and math.isfinite(self.interval_s) else 0
        out = []
        for b in banks:
            needs = (b.max_resident_s * lifetime_scale) >= self.retention_s
            refreshed = ticks > 0 and self.would_refresh(b, lifetime_scale)
            read_j = restore_j = hidden_j = 0.0
            count = hidden = rows = 0
            stall = 0.0
            pulse_words = max(self.pulse_chunks(b), default=0)
            exceeds = (refreshed and math.isfinite(self.interval_s)
                       and port_service_s(pulse_words, freq_hz)
                       > self.interval_s)
            if refreshed:
                # ∫occ·dt / interval — fractional intervals included, so a
                # short iteration still pays its pro-rata share
                bit_intervals = b.occ_bit_s / self.interval_s
                read_j = bit_intervals * refresh_read_pj_per_bit * 1e-12
                restore_j = bit_intervals * refresh_restore_pj_per_bit * 1e-12
                pulses = None if placements is None \
                    else placements.get(b.index, [])
                if pulses is None and pulse_stats is not None:
                    # the vector backend's pre-reduced placement totals —
                    # identical to the placements branch below, which
                    # computes the same folds from the placement list
                    count, stall, hidden = pulse_stats.get(
                        b.index, (0, 0.0, 0))
                    if count:
                        hidden_j = (read_j + restore_j) * hidden / count
                    if self.granularity == "row":
                        rows = count
                elif pulses is None:
                    # additive model: each retention tick serializes the
                    # ports for the bank's full resident words — the row
                    # pulses of one tick sum to the same port time, so
                    # the additive total is granularity-invariant.  The
                    # pulse count matches the timeline model's unit
                    # (ticks under bank granularity, individual row
                    # pulses under row granularity) so the two timings
                    # stay cross-comparable
                    stall = ticks * port_service_s(b.peak_words, freq_hz)
                    if self.granularity == "row":
                        rows = count = ticks * len(self.pulse_chunks(b))
                    else:
                        count = ticks
                else:
                    # p.rows is the pulse multiplicity (1 except for an
                    # aggregated preempting run of row pulses)
                    count = sum(p.rows for p in pulses)
                    stall = sum(p.stall_s for p in pulses)
                    hidden = sum(p.rows for p in pulses if p.hidden)
                    if count:
                        hidden_j = (read_j + restore_j) * hidden / count
                    if self.granularity == "row":
                        rows = count
                b.refresh_count += count
                b.refresh_bits += bit_intervals
                b.refresh_hidden += hidden
                b.stall_s += stall
            out.append(RefreshDecision(bank=b.index, refreshed=refreshed,
                                       needs_refresh=needs,
                                       refresh_j=read_j + restore_j,
                                       refresh_count=count, stall_s=stall,
                                       refresh_read_j=read_j,
                                       refresh_restore_j=restore_j,
                                       hidden_count=hidden,
                                       refresh_hidden_j=hidden_j,
                                       pulse_exceeds_retention=exceeds,
                                       rows_refreshed=rows))
        return out
