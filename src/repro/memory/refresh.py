"""Per-bank refresh scheduling (CAMEL §V-D, Figs 22/23).

Policies:

``always``
    Conventional DRAM discipline: every bank holding data is refreshed
    each retention interval, whether its contents need it or not.
``none``
    No refresh at all — only safe when every resident tensor's lifetime is
    under retention (the pure co-design operating point, Fig 23).
``selective``
    The CAMEL controller: a bank is refreshed only while its longest
    resident lifetime exceeds the retention floor; banks whose tensors all
    die young are skipped.  Energy falls between ``none`` and ``always``
    and no over-retention bank is ever left unrefreshed.

The interval is temperature-adaptive — ``retention_s(temp_c) / guard`` —
so the same schedule tightens automatically as the die heats up (Fig 22).
Refresh energy integrates each refreshed bank's occupancy over time
(∫occ·dt / interval × pJ/bit): a bank half-full for half the iteration
costs a quarter of a full bank, which the scalar ``edram_energy`` model
(peak-bits × intervals) can only upper-bound.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import edram as ed
from repro.memory.banks import BankState, port_service_s

REFRESH_POLICIES = ("always", "none", "selective")


@dataclasses.dataclass(frozen=True)
class RefreshDecision:
    bank: int
    refreshed: bool
    needs_refresh: bool        # max resident lifetime ≥ retention
    refresh_j: float           # read + restore total
    refresh_count: int
    stall_s: float
    refresh_read_j: float = 0.0     # sense phase
    refresh_restore_j: float = 0.0  # write-back phase


class RefreshScheduler:
    """Decides which banks to refresh and accounts energy + port stalls.

    ``retention_s`` overrides the temperature-derived retention floor —
    pass ``math.inf`` to model a static technology (the SRAM baseline's
    controller replay) that never needs refresh.
    """

    def __init__(self, policy: str, temp_c: float, guard: float = 1.0,
                 interval_s: float | None = None,
                 retention_s: float | None = None):
        if policy not in REFRESH_POLICIES:
            raise ValueError(f"unknown refresh policy {policy!r}; "
                             f"choose from {REFRESH_POLICIES}")
        self.policy = policy
        self.temp_c = temp_c
        self.retention_s = (retention_s if retention_s is not None
                            else ed.retention_s(temp_c))
        self.interval_s = (interval_s if interval_s is not None
                           else ed.refresh_interval_s(temp_c, guard))

    def needs_refresh(self, bank: BankState) -> bool:
        """The per-bank co-design criterion (eq 10 at bank granularity)."""
        return bank.max_resident_s >= self.retention_s

    def account(self, banks: Sequence[BankState], duration_s: float,
                freq_hz: float, refresh_read_pj_per_bit: float,
                refresh_restore_pj_per_bit: float,
                lifetime_scale: float = 1.0) -> list[RefreshDecision]:
        """Charge refresh energy/stalls for one iteration of ``duration_s``.

        Refresh energy is split into the sense/read phase and the
        write-back/restore phase (``EDRAMConfig.refresh_read_pj`` /
        ``refresh_restore_pj``); ``RefreshDecision.refresh_j`` stays the
        total so existing consumers are unchanged.

        ``lifetime_scale`` rescales observed residency durations before the
        retention comparison.  Since ``BankState`` now scales residencies
        per tensor at free/finalize time (``_Residency.scale``), callers
        that pre-scale should pass the default 1.0.

        Mutates each bank's ``refresh_count``/``refresh_bits``/``stall_s``
        counters and returns per-bank decisions.
        """
        ticks = math.ceil(duration_s / self.interval_s) \
            if duration_s > 0 and math.isfinite(self.interval_s) else 0
        out = []
        for b in banks:
            needs = (b.max_resident_s * lifetime_scale) >= self.retention_s
            held_data = b.occ_bit_s > 0
            refreshed = held_data and ticks > 0 and (
                self.policy == "always"
                or (self.policy == "selective" and needs))
            read_j = restore_j = 0.0
            count = 0
            stall = 0.0
            if refreshed:
                # ∫occ·dt / interval — fractional intervals included, so a
                # short iteration still pays its pro-rata share
                bit_intervals = b.occ_bit_s / self.interval_s
                read_j = bit_intervals * refresh_read_pj_per_bit * 1e-12
                restore_j = bit_intervals * refresh_restore_pj_per_bit * 1e-12
                count = ticks
                # each refresh pulse occupies the ports for its resident
                # words (read + restore through the same word line)
                words = b.peak_words
                stall = count * port_service_s(words, freq_hz)
                b.refresh_count += count
                b.refresh_bits += bit_intervals
                b.stall_s += stall
            out.append(RefreshDecision(bank=b.index, refreshed=refreshed,
                                       needs_refresh=needs,
                                       refresh_j=read_j + restore_j,
                                       refresh_count=count, stall_s=stall,
                                       refresh_read_j=read_j,
                                       refresh_restore_j=restore_j))
        return out
