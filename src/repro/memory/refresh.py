"""Per-bank refresh scheduling (CAMEL §V-D, Figs 22/23).

Policies:

``always``
    Conventional DRAM discipline: every bank holding data is refreshed
    each retention interval, whether its contents need it or not.
``none``
    No refresh at all — only safe when every resident tensor's lifetime is
    under retention (the pure co-design operating point, Fig 23).
``selective``
    The CAMEL controller: a bank is refreshed only while its longest
    resident lifetime exceeds the retention floor; banks whose tensors all
    die young are skipped.  Energy falls between ``none`` and ``always``
    and no over-retention bank is ever left unrefreshed.

The interval is temperature-adaptive — ``retention_s(temp_c) / guard`` —
so the same schedule tightens automatically as the die heats up (Fig 22).
Refresh energy integrates each refreshed bank's occupancy over time
(∫occ·dt / interval × pJ/bit): a bank half-full for half the iteration
costs a quarter of a full bank, which the scalar ``edram_energy`` model
(peak-bits × intervals) can only upper-bound.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core import edram as ed
from repro.memory.banks import BankState, port_service_s

REFRESH_POLICIES = ("always", "none", "selective")


@dataclasses.dataclass(frozen=True)
class RefreshDecision:
    bank: int
    refreshed: bool
    needs_refresh: bool        # max resident lifetime ≥ retention
    refresh_j: float           # read + restore total (J)
    refresh_count: int
    stall_s: float             # port time not hidden under compute (s)
    refresh_read_j: float = 0.0     # sense phase (J)
    refresh_restore_j: float = 0.0  # write-back phase (J)
    # timeline model only: pulses that landed in bank-idle windows, and
    # the share of refresh_j they carry (energy still paid, time hidden)
    hidden_count: int = 0
    refresh_hidden_j: float = 0.0
    # the can-never-hide case (ROADMAP): this bank's pulse needs more
    # continuous port time than one retention interval provides, so no
    # idle window can ever fit it — every pulse stalls, by construction
    pulse_exceeds_retention: bool = False


@dataclasses.dataclass(frozen=True)
class PulsePlacement:
    """One refresh pulse placed on the event-interleaved timeline.

    ``deadline_s`` is the end of the pulse's retention interval; the
    scheduler tries to start the pulse at ``start_s`` inside a bank-idle
    window before that deadline.  ``hidden`` pulses cost energy but no
    time; a pulse with no idle window preempts the ports at its deadline
    and charges ``stall_s`` seconds of serialization.
    """
    bank: int
    index: int                 # 1-based retention tick
    deadline_s: float
    start_s: float
    hidden: bool
    stall_s: float


class RefreshScheduler:
    """Decides which banks to refresh and accounts energy + port stalls.

    ``retention_s`` overrides the temperature-derived retention floor —
    pass ``math.inf`` to model a static technology (the SRAM baseline's
    controller replay) that never needs refresh.
    """

    def __init__(self, policy: str, temp_c: float, guard: float = 1.0,
                 interval_s: float | None = None,
                 retention_s: float | None = None):
        if policy not in REFRESH_POLICIES:
            raise ValueError(f"unknown refresh policy {policy!r}; "
                             f"choose from {REFRESH_POLICIES}")
        self.policy = policy
        self.temp_c = temp_c
        self.retention_s = (retention_s if retention_s is not None
                            else ed.retention_s(temp_c))
        # an overridden retention floor implies the interval too (an SRAM
        # replay's inf retention must not report a finite eDRAM interval)
        if interval_s is not None:
            self.interval_s = interval_s
        elif retention_s is not None:
            self.interval_s = retention_s / max(guard, 1e-9)
        else:
            self.interval_s = ed.refresh_interval_s(temp_c, guard)

    def needs_refresh(self, bank: BankState) -> bool:
        """The per-bank co-design criterion (eq 10 at bank granularity)."""
        return bank.max_resident_s >= self.retention_s

    def would_refresh(self, bank: BankState,
                      lifetime_scale: float = 1.0) -> bool:
        """Whether the policy refreshes ``bank`` at all this iteration:
        the bank must hold data, and under ``selective`` its longest
        resident data lifetime must reach the retention floor."""
        needs = (bank.max_resident_s * lifetime_scale) >= self.retention_s
        held_data = bank.occ_bit_s > 0
        return held_data and (self.policy == "always"
                              or (self.policy == "selective" and needs))

    def place_pulses(self, bank: BankState, duration_s: float,
                     freq_hz: float) -> list[PulsePlacement]:
        """Deadline-driven pulse placement for the timeline model.

        One pulse per retention tick (``interval_s``) over ``duration_s``
        seconds of timeline.  Each pulse needs the bank's ports for
        ``port_service_s(peak_words)`` seconds (read the droop + restore
        through the same word line); the scheduler looks for a bank-idle
        window of that length inside the pulse's own retention interval
        ``[(k-1)·I, min(k·I, duration_s)]``.  A window found ⇒ the pulse
        is *hidden* under compute (energy charged, zero stall); no window
        ⇒ the pulse preempts at its deadline and charges its full port
        time as ``stall_s``.

        Pure query — mutates nothing; feed the result to :meth:`account`
        via ``placements`` to commit counters and energy.
        """
        if duration_s <= 0 or not math.isfinite(self.interval_s):
            return []
        pulse_s = port_service_s(bank.peak_words, freq_hz)
        ticks = math.ceil(duration_s / self.interval_s)
        out = []
        for k in range(1, ticks + 1):
            lo = (k - 1) * self.interval_s
            deadline = min(k * self.interval_s, duration_s)
            start = bank.idle_window(lo, deadline, pulse_s)
            hidden = start is not None
            out.append(PulsePlacement(
                bank=bank.index, index=k, deadline_s=deadline,
                start_s=start if hidden else deadline, hidden=hidden,
                stall_s=0.0 if hidden else pulse_s))
        return out

    def account(self, banks: Sequence[BankState], duration_s: float,
                freq_hz: float, refresh_read_pj_per_bit: float,
                refresh_restore_pj_per_bit: float,
                lifetime_scale: float = 1.0,
                placements: Optional[dict] = None) -> list[RefreshDecision]:
        """Charge refresh energy/stalls for one iteration of ``duration_s``.

        Args:
            banks: the ``BankState`` objects the replay populated.
            duration_s: iteration length in **seconds** (the timeline
                makespan when the caller uses the timeline model).
            freq_hz: port clock — one word moves per cycle per port.
            refresh_read_pj_per_bit: sense-phase energy, **pJ/bit**.
            refresh_restore_pj_per_bit: write-back energy, **pJ/bit**.
            lifetime_scale: rescales observed residency durations before
                the retention comparison.  ``BankState`` already scales
                residencies per tensor (``_Residency.scale``), so callers
                that pre-scale pass the default 1.0.
            placements: optional ``{bank index: [PulsePlacement, ...]}``
                from :meth:`place_pulses` (the timeline model).  When
                given, a bank's stall is the sum of its *unhidden* pulse
                stalls instead of full per-pulse serialization, and the
                energy of hidden pulses is surfaced as
                ``refresh_hidden_j``.

        Returns:
            One :class:`RefreshDecision` per bank (energy in **J**,
            stalls in **s**).  Refresh energy integrates occupancy over
            time (∫occ·dt / interval × pJ/bit) and is split into the
            sense/read and restore/write-back phases;
            ``RefreshDecision.refresh_j`` stays the total.  A refreshed
            bank whose pulse width ``port_service_s(peak_words)`` exceeds
            the retention interval is flagged
            ``pulse_exceeds_retention`` — it can never hide (note the
            pulse width scales with 1/``freq_hz`` while the interval is
            wall-clock, so clocking down can trip this).

        Mutates each bank's ``refresh_count`` / ``refresh_bits`` /
        ``refresh_hidden`` / ``stall_s`` counters.
        """
        ticks = math.ceil(duration_s / self.interval_s) \
            if duration_s > 0 and math.isfinite(self.interval_s) else 0
        out = []
        for b in banks:
            needs = (b.max_resident_s * lifetime_scale) >= self.retention_s
            refreshed = ticks > 0 and self.would_refresh(b, lifetime_scale)
            read_j = restore_j = hidden_j = 0.0
            count = hidden = 0
            stall = 0.0
            exceeds = (refreshed and math.isfinite(self.interval_s)
                       and port_service_s(b.peak_words, freq_hz)
                       > self.interval_s)
            if refreshed:
                # ∫occ·dt / interval — fractional intervals included, so a
                # short iteration still pays its pro-rata share
                bit_intervals = b.occ_bit_s / self.interval_s
                read_j = bit_intervals * refresh_read_pj_per_bit * 1e-12
                restore_j = bit_intervals * refresh_restore_pj_per_bit * 1e-12
                pulses = None if placements is None \
                    else placements.get(b.index, [])
                if pulses is None:
                    # additive model: each pulse serializes the ports for
                    # the bank's resident words
                    count = ticks
                    stall = count * port_service_s(b.peak_words, freq_hz)
                else:
                    count = len(pulses)
                    stall = sum(p.stall_s for p in pulses)
                    hidden = sum(1 for p in pulses if p.hidden)
                    if count:
                        hidden_j = (read_j + restore_j) * hidden / count
                b.refresh_count += count
                b.refresh_bits += bit_intervals
                b.refresh_hidden += hidden
                b.stall_s += stall
            out.append(RefreshDecision(bank=b.index, refreshed=refreshed,
                                       needs_refresh=needs,
                                       refresh_j=read_j + restore_j,
                                       refresh_count=count, stall_s=stall,
                                       refresh_read_j=read_j,
                                       refresh_restore_j=restore_j,
                                       hidden_count=hidden,
                                       refresh_hidden_j=hidden_j,
                                       pulse_exceeds_retention=exceeds))
        return out
