"""Hybrid SRAM+eDRAM memory tiering and pluggable placement policies
(MCAIMem, arXiv 2312.03559; CAMEL §V).

CAMEL's allocator places every tensor into one homogeneous bank array.
This module generalizes that in two steps:

1.  **Placement as a strategy.**  The bank-preference logic that was
    hard-coded in ``Allocator._tiers``/``Allocator.place`` is a
    :class:`PlacementPolicy` object: ``bank_order`` returns bank
    *positions* in preference groups, ``dense`` picks dense packing vs
    bandwidth striping, and ``placed`` is the post-placement hook (the
    ping-pong rotation).  The three classic policies (``pingpong`` /
    ``first_fit`` / ``lifetime``) are bit-identical to the hard-coded
    originals — every pre-tier golden pin transfers through the seam
    unchanged (``tests/test_tiers.py``).

2.  **Tiers as first-class hardware.**  A :class:`TierSpec` describes
    one on-chip tier (cell type, bank geometry, retention, access/
    refresh/leakage energies — the SRAM numbers come from the comparison
    points on :class:`~repro.core.edram.EDRAMConfig`), and a
    :class:`MemorySystem` composes one
    :class:`~repro.memory.allocator.Allocator` per tier behind the same
    interface the trace replay drives.  A :class:`TierPolicy` routes
    each tensor to a tier *first* (``lifetime_tiered``: sub-retention
    transients → dense eDRAM, over-retention tensors → refresh-free
    SRAM, with cross-tier fallback when the preferred tier is full and a
    whole-tensor off-chip spill only when every tier is), then the
    tier's own single-tier policy picks banks within it.  A tensor lives
    wholly in one tier — striping a BFP group across cell types would
    split its shared exponent from its mantissas.

:func:`iso_area_tiers` builds the area-neutral capacity split the
``sim.sweep(splits=...)`` axis and the ``Hybrid+CAMEL`` arm family
sweep: at ``sram_split = s``, the silicon that held the all-eDRAM array
is re-divided so a fraction ``s`` of it becomes SRAM at
``1/density_vs_sram`` the capacity — ``s = 0`` is the stock eDRAM
array, ``s = 1`` is exactly the FR baseline's 4×48 KB SRAM.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.memory.banks import BankGeometry

# single-tier (within-tier) placement policies — the classic allocator
# policies, now pluggable.  Kept here (not in allocator.py) so the
# allocator imports the seam rather than hard-coding it.
ALLOC_POLICIES = ("pingpong", "first_fit", "lifetime")

# tier-routing policies a MemorySystem resolves (tensor → tier order)
TIER_POLICIES = ("lifetime_tiered", "tiered_first_fit")

CELL_KINDS = ("edram", "sram")


# ------------------------------------------------------------- tier spec

@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One on-chip memory tier: cell type, bank geometry, energies.

    ``retention_s=None`` means the cell default — the temperature-derived
    eDRAM retention curve for ``cell="edram"``, never-decays
    (``math.inf`` at replay time) for ``cell="sram"``.  Kept ``None`` in
    the spec itself so ``dataclasses.asdict``/JSON round-trips stay
    strict-JSON safe (``inf`` is not representable).
    """
    name: str
    cell: str = "edram"
    n_banks: int = 12
    bank_kb: float = 32.0
    word_bits: int = 58
    rows_per_bank: int = 1024
    retention_s: Optional[float] = None
    read_pj_per_bit: float = 0.013
    write_pj_per_bit: float = 0.017
    refresh_read_pj_per_bit: float = 0.008
    refresh_restore_pj_per_bit: float = 0.012
    leakage_mw_per_kb: float = 0.004

    def __post_init__(self):
        if self.cell not in CELL_KINDS:
            raise ValueError(f"unknown cell kind {self.cell!r}; "
                             f"choose from {CELL_KINDS}")

    @classmethod
    def edram(cls, cfg, *, name: str = "edram",
              n_banks: Optional[int] = None,
              bank_kb: Optional[float] = None) -> "TierSpec":
        """An eDRAM tier drawn from an ``EDRAMConfig``'s native fields."""
        return cls(
            name=name, cell="edram",
            n_banks=cfg.n_banks if n_banks is None else n_banks,
            bank_kb=cfg.bank_kb if bank_kb is None else bank_kb,
            word_bits=cfg.word_bits, rows_per_bank=cfg.words_per_bank,
            read_pj_per_bit=cfg.read_pj_per_bit,
            write_pj_per_bit=cfg.write_pj_per_bit,
            refresh_read_pj_per_bit=cfg.refresh_read_pj,
            refresh_restore_pj_per_bit=cfg.refresh_restore_pj,
            leakage_mw_per_kb=cfg.leakage_mw_per_kb)

    @classmethod
    def sram(cls, cfg, *, name: str = "sram", n_banks: int = 4,
             bank_kb: float = 48.0,
             word_bits: Optional[int] = None) -> "TierSpec":
        """An SRAM tier drawn from the ``EDRAMConfig`` comparison points
        (6T, same node).  In a hybrid array it stores the same BFP word
        as the eDRAM tier (``word_bits`` defaults to the config's), so a
        tensor can move between tiers without repacking."""
        return cls(
            name=name, cell="sram", n_banks=n_banks, bank_kb=bank_kb,
            word_bits=cfg.word_bits if word_bits is None else word_bits,
            rows_per_bank=0,
            read_pj_per_bit=cfg.sram_read_pj_per_bit,
            write_pj_per_bit=cfg.sram_write_pj_per_bit,
            refresh_read_pj_per_bit=0.0,
            refresh_restore_pj_per_bit=0.0,
            leakage_mw_per_kb=cfg.sram_leakage_mw_per_kb)

    def geometry(self) -> BankGeometry:
        words = int(self.bank_kb * 1024 * 8 // self.word_bits)
        return BankGeometry(word_bits=self.word_bits,
                            words_per_bank=words,
                            n_banks=self.n_banks,
                            rows_per_bank=self.rows_per_bank)

    @property
    def capacity_kb(self) -> float:
        return self.n_banks * self.bank_kb

    @property
    def capacity_bits(self) -> float:
        return self.capacity_kb * 1024 * 8

    @property
    def leakage_mw(self) -> float:
        """Static leakage power of the whole tier (mW)."""
        return self.leakage_mw_per_kb * self.capacity_kb


def iso_area_tiers(cfg, sram_split: float, *,
                   sram_banks: int = 4) -> tuple:
    """The area-neutral SRAM:eDRAM capacity split at ``sram_split`` ∈
    [0, 1] (the ``splits=`` sweep axis).

    The all-eDRAM array (``cfg.n_banks × cfg.bank_kb``) occupies a fixed
    silicon area; giving a fraction ``s`` of that area to 6T SRAM yields
    ``s × total_kb / density_vs_sram`` of SRAM capacity and leaves
    ``(1-s) × total_kb`` of eDRAM.  Bank *counts* stay fixed and bank
    capacity shrinks, so port bandwidth is split-invariant.  Endpoint
    tiers with zero capacity are omitted: ``s=0`` returns the stock
    eDRAM tier alone; ``s=1`` returns only the SRAM tier — at the
    default ``density_vs_sram=2.0`` exactly the FR baseline's 4×48 KB.
    """
    s = float(sram_split)
    if not 0.0 <= s <= 1.0:
        raise ValueError(f"sram_split must be in [0, 1], got {s!r}")
    total_kb = cfg.n_banks * cfg.bank_kb
    sram_total_kb = total_kb / cfg.density_vs_sram
    out = []
    if s < 1.0:
        out.append(TierSpec.edram(cfg, bank_kb=cfg.bank_kb * (1.0 - s)))
    if s > 0.0:
        out.append(TierSpec.sram(cfg, n_banks=sram_banks,
                                 bank_kb=sram_total_kb * s / sram_banks))
    return tuple(out)


# --------------------------------------------- single-tier placement seam

class PlacementPolicy:
    """Strategy deciding *where in one tier's banks* a tensor goes.

    All three methods receive the owning
    :class:`~repro.memory.allocator.Allocator` (they read its ``banks``,
    ``placements``, ``retention_s`` and — for ping-pong — its
    ``_next_bank`` rotation state, which stays on the allocator so
    policy objects are stateless singletons).

    ``bank_order`` returns bank **positions** (indices into
    ``alloc.banks``) grouped into preference tiers: striping spreads a
    tensor across one group before touching the next.  Positions, not
    ``BankState.index`` — a :class:`MemorySystem` renumbers bank indices
    globally across tiers, while each sub-allocator keeps addressing its
    own list positionally.
    """

    name = "abstract"

    def bank_order(self, alloc, expected_lifetime_s) -> list:
        raise NotImplementedError

    def dense(self, alloc, expected_lifetime_s) -> bool:
        """Dense packing (fill banks in order) vs bandwidth striping."""
        return False

    def placed(self, alloc, spans) -> None:
        """Post-placement hook (the ping-pong rotation)."""


class PingPongPolicy(PlacementPolicy):
    """FIFO ping-pong placement (Fig 17): each new tensor starts at the
    bank after the previous allocation's first bank, so producer/consumer
    tensors of adjacent ops land in different banks."""

    name = "pingpong"

    def bank_order(self, alloc, expected_lifetime_s) -> list:
        n = len(alloc.banks)
        return [[(alloc._next_bank + i) % n for i in range(n)]]

    def placed(self, alloc, spans) -> None:
        if spans:
            alloc._next_bank = (spans[0][0] + 1) % len(alloc.banks)


class FirstFitPolicy(PlacementPolicy):
    """Lowest-position bank with space — densest packing, worst
    conflicts."""

    name = "first_fit"

    def bank_order(self, alloc, expected_lifetime_s) -> list:
        return [list(range(len(alloc.banks)))]

    def dense(self, alloc, expected_lifetime_s) -> bool:
        return True


class LifetimePolicy(PlacementPolicy):
    """Lifetime-aware coloring: tensors under the retention floor are
    steered away from banks holding over-retention tensors (and vice
    versa), so short-lived data shares banks the ``selective`` refresh
    policy can leave entirely unrefreshed.  Over-retention tensors pack
    densely (poison as few banks as possible); short-lived ones stripe
    for bandwidth."""

    name = "lifetime"

    def bank_order(self, alloc, expected_lifetime_s) -> list:
        short = (alloc.retention_s is None or expected_lifetime_s is None
                 or expected_lifetime_s < alloc.retention_s)
        match, other, empty = [], [], []
        for pos, b in enumerate(alloc.banks):
            if not b.resident:
                empty.append(pos)
                continue
            # classify by what is resident *now*: any tensor expected to
            # outlive retention poisons the bank for short-lived data
            bank_short = all(
                alloc.placements[t].expected_lifetime_s is None
                or alloc.retention_s is None
                or alloc.placements[t].expected_lifetime_s
                < alloc.retention_s
                for t in b.resident)
            (match if bank_short == short else other).append(pos)
        return [match, empty, other]

    def dense(self, alloc, expected_lifetime_s) -> bool:
        return (alloc.retention_s is not None
                and expected_lifetime_s is not None
                and expected_lifetime_s >= alloc.retention_s)


PLACEMENT_POLICIES = {
    "pingpong": PingPongPolicy(),
    "first_fit": FirstFitPolicy(),
    "lifetime": LifetimePolicy(),
}


def resolve_placement_policy(policy) -> PlacementPolicy:
    """Resolve a policy name (``ALLOC_POLICIES``) or a
    :class:`PlacementPolicy` instance; ``ValueError`` otherwise."""
    if isinstance(policy, PlacementPolicy):
        return policy
    try:
        return PLACEMENT_POLICIES[policy]
    except (KeyError, TypeError):
        raise ValueError(f"unknown alloc policy {policy!r}; "
                         f"choose from {ALLOC_POLICIES}") from None


# ------------------------------------------------------ tier-routing seam

class TierPolicy:
    """Strategy deciding *which tier* a tensor prefers.  ``tier_order``
    returns tier indices into ``system.tiers`` in preference order; the
    :class:`MemorySystem` takes the first tier with room (cross-tier
    fallback) and spills off-chip only when none has."""

    name = "abstract"

    def tier_order(self, system, expected_lifetime_s) -> list:
        raise NotImplementedError


class TieredFirstFitPolicy(TierPolicy):
    """Tiers in declared order, lifetime-blind — the degenerate routing
    that reduces a multi-tier system to capacity overflow."""

    name = "tiered_first_fit"

    def tier_order(self, system, expected_lifetime_s) -> list:
        return list(range(len(system.tiers)))


class LifetimeTieredPolicy(TierPolicy):
    """MCAIMem routing: tensors whose expected data lifetime is under
    the eDRAM retention floor go to the dense eDRAM tier; tensors that
    would force refresh there go to the refresh-free SRAM tier.  Unknown
    lifetimes are treated as short-lived (matching the single-tier
    ``lifetime`` policy's convention)."""

    name = "lifetime_tiered"

    def tier_order(self, system, expected_lifetime_s) -> list:
        edram = [k for k, t in enumerate(system.tiers)
                 if t.cell == "edram"]
        sram = [k for k, t in enumerate(system.tiers) if t.cell != "edram"]
        floor = min((system.retentions[k] for k in edram),
                    default=math.inf)
        short = (expected_lifetime_s is None
                 or expected_lifetime_s < floor)
        return edram + sram if short else sram + edram


TIER_POLICY_REGISTRY = {
    "lifetime_tiered": LifetimeTieredPolicy(),
    "tiered_first_fit": TieredFirstFitPolicy(),
}


def resolve_tier_policy(policy) -> TierPolicy:
    if isinstance(policy, TierPolicy):
        return policy
    try:
        return TIER_POLICY_REGISTRY[policy]
    except (KeyError, TypeError):
        raise ValueError(f"unknown tier policy {policy!r}; "
                         f"choose from {TIER_POLICIES}") from None


# --------------------------------------------------------- memory system

class MemorySystem:
    """N memory tiers behind the single-allocator interface the trace
    replay drives (``place``/``rewrite``/``free``/``touch``/``evict``/
    ``location``, plus the ``banks``/``spill_bits``/``spilled``/
    ``evicted`` counters the report reads).

    Each tier owns a full :class:`~repro.memory.allocator.Allocator`
    over its own :class:`~repro.memory.banks.BankGeometry`; bank
    ``index`` attributes are renumbered globally (tier 0's banks first),
    so the flat ``banks`` list, the per-op bank-word tables, and the
    timeline walk all address one global bank namespace.  A tensor lives
    wholly in one tier — the fit check is per tier, and a tensor no tier
    can hold spills off-chip whole (partial spills would split a BFP
    group's shared exponent from its mantissas).

    ``retentions`` carries each tier's resolved retention floor in
    seconds (``math.inf`` for SRAM) — the routing policy and the
    within-tier lifetime coloring both read it.
    """

    def __init__(self, tiers: Sequence[TierSpec],
                 retentions: Sequence[float],
                 policy: str = "lifetime_tiered",
                 within: str = "pingpong"):
        from repro.memory.allocator import Allocator
        self.tiers = tuple(tiers)
        if not self.tiers:
            raise ValueError("MemorySystem needs at least one tier")
        if len(retentions) != len(self.tiers):
            raise ValueError("one retention floor per tier required")
        if len({t.word_bits for t in self.tiers}) != 1:
            raise ValueError(
                "all tiers must share word_bits: a tensor's BFP words "
                "must be movable between tiers without repacking")
        self.retentions = [float(r) for r in retentions]
        self._tier_policy = resolve_tier_policy(policy)
        self.policy = self._tier_policy.name
        self.allocs = []
        self.offsets = []
        self.banks = []
        offset = 0
        for t, ret in zip(self.tiers, self.retentions):
            a = Allocator(t.geometry(), policy=within,
                          retention_s=ret if math.isfinite(ret) else None)
            for j, b in enumerate(a.banks):
                b.index = offset + j
            self.offsets.append(offset)
            offset += len(a.banks)
            self.allocs.append(a)
            self.banks.extend(a.banks)
        self.placements: dict = {}
        self._tier_of: dict = {}
        self.spill_bits = 0.0
        self.spilled: list = []
        self.evicted: list = []

    # -- geometry helpers -------------------------------------------------
    def words_for(self, bits: float) -> int:
        return self.allocs[0].geometry.words_for(bits)

    def tier_of_bank(self, bank_index: int) -> int:
        """Tier index owning global bank ``bank_index``."""
        for k in range(len(self.offsets) - 1, -1, -1):
            if bank_index >= self.offsets[k]:
                return k
        raise IndexError(f"no tier owns bank {bank_index}")

    def tier_banks(self, k: int) -> list:
        lo = self.offsets[k]
        return self.banks[lo:lo + self.tiers[k].n_banks]

    def tier_of_tensor(self, tensor: str) -> Optional[int]:
        return self._tier_of.get(tensor)

    # -- allocation (Allocator-compatible interface) ----------------------
    def place(self, tensor: str, bits: float, now: float,
              expected_lifetime_s: Optional[float] = None,
              lifetime_scale: float = 1.0, reserve_words: int = 0):
        from repro.memory.allocator import Placement
        if tensor in self.placements:
            raise ValueError(f"{tensor} already placed")
        need = self.words_for(bits)
        order = self._tier_policy.tier_order(self, expected_lifetime_s)
        chosen = None
        for k in order:
            free = sum(b.free_words for b in self.allocs[k].banks) \
                - max(0, reserve_words)
            if need <= free:
                chosen = k
                break
        if chosen is None:
            self.spill_bits += bits
            self.spilled.append(tensor)
            p = Placement(tensor, bits, spans=(),
                          expected_lifetime_s=expected_lifetime_s)
            self.placements[tensor] = p
            return p
        # the fit pre-check above replicates the sub-allocator's own
        # spill test, so this delegation can never record a tier spill
        local = self.allocs[chosen].place(
            tensor, bits, now, expected_lifetime_s=expected_lifetime_s,
            lifetime_scale=lifetime_scale, reserve_words=reserve_words)
        off = self.offsets[chosen]
        p = Placement(tensor, bits,
                      spans=tuple((off + i, w) for i, w in local.spans),
                      expected_lifetime_s=expected_lifetime_s)
        self.placements[tensor] = p
        self._tier_of[tensor] = chosen
        return p

    def rewrite(self, tensor: str, now: float):
        k = self._tier_of.get(tensor)
        if k is not None:
            self.allocs[k].rewrite(tensor, now)
        return self.placements[tensor]

    def free(self, tensor: str, now: float) -> None:
        p = self.placements.pop(tensor, None)
        if p is None:
            return
        k = self._tier_of.pop(tensor, None)
        if k is not None:
            self.allocs[k].free(tensor, now)

    def touch(self, tensor: str, now: float) -> None:
        k = self._tier_of.get(tensor)
        if k is not None:
            self.allocs[k].touch(tensor, now)

    def evict(self, tensor: str, now: float) -> None:
        if tensor in self.placements:
            self.evicted.append(tensor)
        self.free(tensor, now)

    # -- introspection ----------------------------------------------------
    def location(self, tensor: str):
        return self.placements.get(tensor)

    @property
    def used_bits(self) -> float:
        return sum(b.occupied_bits for b in self.banks)

    def occupancy(self) -> list:
        """Per-bank fill fraction across all tiers, in global bank
        order."""
        return [b.used_words / b.geometry.words_per_bank
                for b in self.banks]
