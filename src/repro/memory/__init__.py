"""Bank-level eDRAM memory-controller subsystem (CAMEL §V, Figs 17/19/23).

Turns the scalar retention/energy model in ``core.edram`` into an
event-driven controller: tensors are placed into 58-bit-word banks
(``allocator``), per-bank occupancy and port contention are tracked
(``banks``), refresh is scheduled per bank — skipped entirely for banks
whose resident data dies before retention (``refresh``) — and the whole
thing is driven by memory traces emitted by ``core.schedule.simulate()``
(``trace``).

Two stall models finish a replayed trace: :func:`replay` (additive —
per-op port overshoot summed, every refresh pulse serializes) and the
closed-loop event-interleaved engine in ``repro.sim.timeline``, which
builds on :func:`replay_core`, the per-bank busy intervals
(``BankState.occupy_port`` / ``idle_window``) and the deadline-driven
pulse placement (``RefreshScheduler.place_pulses``).
"""
from repro.memory.banks import BankGeometry, BankState, port_service_s
from repro.memory.allocator import ALLOC_POLICIES, Allocator, Placement
from repro.memory.refresh import (REFRESH_GRANULARITIES, REFRESH_POLICIES,
                                  PulsePlacement, RefreshDecision,
                                  RefreshScheduler)
from repro.memory.trace import (REPLAY_BACKENDS, BankReport,
                                ControllerReport, ReplayCore, TraceEvent,
                                build_report, merge_traces, replay,
                                replay_core, resolve_backend)

__all__ = [
    "ALLOC_POLICIES", "Allocator", "BankGeometry", "BankReport", "BankState",
    "ControllerReport", "Placement", "PulsePlacement",
    "REFRESH_GRANULARITIES", "REFRESH_POLICIES", "REPLAY_BACKENDS",
    "RefreshDecision", "RefreshScheduler", "ReplayCore", "TraceEvent",
    "build_report", "merge_traces", "port_service_s", "replay",
    "replay_core", "resolve_backend",
]
