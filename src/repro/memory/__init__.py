"""Bank-level eDRAM memory-controller subsystem (CAMEL §V, Figs 17/19/23).

Turns the scalar retention/energy model in ``core.edram`` into an
event-driven controller: tensors are placed into 58-bit-word banks
(``allocator``), per-bank occupancy and port contention are tracked
(``banks``), refresh is scheduled per bank — skipped entirely for banks
whose resident data dies before retention (``refresh``) — and the whole
thing is driven by memory traces emitted by ``core.schedule.simulate()``
(``trace``).

Two stall models finish a replayed trace: :func:`replay` (additive —
per-op port overshoot summed, every refresh pulse serializes) and the
closed-loop event-interleaved engine in ``repro.sim.timeline``, which
builds on :func:`replay_core`, the per-bank busy intervals
(``BankState.occupy_port`` / ``idle_window``) and the deadline-driven
pulse placement (``RefreshScheduler.place_pulses``).

Placement is a pluggable strategy (``tiers``): the classic policies are
:class:`PlacementPolicy` singletons, and a hybrid SRAM+eDRAM
:class:`MemorySystem` (one allocator per :class:`TierSpec`, tier routing
via :class:`TierPolicy` — MCAIMem's ``lifetime_tiered``) drops in behind
the same replay interface.  Build iso-area SRAM:eDRAM splits with
:func:`iso_area_tiers`.
"""
from repro.memory.banks import BankGeometry, BankState, port_service_s
from repro.memory.tiers import (ALLOC_POLICIES, TIER_POLICIES,
                                MemorySystem, PlacementPolicy, TierPolicy,
                                TierSpec, iso_area_tiers,
                                resolve_placement_policy,
                                resolve_tier_policy)
from repro.memory.allocator import Allocator, Placement
from repro.memory.refresh import (REFRESH_GRANULARITIES, REFRESH_POLICIES,
                                  PulsePlacement, RefreshDecision,
                                  RefreshScheduler)
from repro.memory.trace import (REPLAY_BACKENDS, BankReport,
                                ControllerReport, ReplayCore, TraceEvent,
                                account_refresh, build_report,
                                merge_traces, replay, replay_core,
                                resolve_backend)

__all__ = [
    "ALLOC_POLICIES", "Allocator", "BankGeometry", "BankReport", "BankState",
    "ControllerReport", "MemorySystem", "Placement", "PlacementPolicy",
    "PulsePlacement", "REFRESH_GRANULARITIES", "REFRESH_POLICIES",
    "REPLAY_BACKENDS", "RefreshDecision", "RefreshScheduler", "ReplayCore",
    "TIER_POLICIES", "TierPolicy", "TierSpec", "TraceEvent",
    "account_refresh", "build_report", "iso_area_tiers", "merge_traces",
    "port_service_s", "replay", "replay_core", "resolve_backend",
    "resolve_placement_policy", "resolve_tier_policy",
]
