"""Bank-level eDRAM memory-controller subsystem (CAMEL §V, Figs 17/19/23).

Turns the scalar retention/energy model in ``core.edram`` into an
event-driven controller: tensors are placed into 58-bit-word banks
(``allocator``), per-bank occupancy and port contention are tracked
(``banks``), refresh is scheduled per bank — skipped entirely for banks
whose resident data dies before retention (``refresh``) — and the whole
thing is driven by memory traces emitted by ``core.schedule.simulate()``
(``trace``).
"""
from repro.memory.banks import BankGeometry, BankState, port_service_s
from repro.memory.allocator import ALLOC_POLICIES, Allocator, Placement
from repro.memory.refresh import REFRESH_POLICIES, RefreshScheduler
from repro.memory.trace import (BankReport, ControllerReport, TraceEvent,
                                merge_traces, replay)

__all__ = [
    "ALLOC_POLICIES", "Allocator", "BankGeometry", "BankReport", "BankState",
    "ControllerReport", "Placement", "REFRESH_POLICIES", "RefreshScheduler",
    "TraceEvent", "merge_traces", "port_service_s", "replay",
]
