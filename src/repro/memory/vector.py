"""Vectorized trace replay (`backend="vector"`) — the numpy interval
engine behind the ``replay_core`` seam.

The reference backend (``repro.memory.trace.replay_core`` + the walks in
``repro.sim.timeline`` / ``RefreshScheduler.place_pulses``) is a scalar
event loop: per-event bank mutation, per-(op, bank) port accounting,
per-pulse gap search.  This module re-derives the same results from
whole-trace arrays:

* **Lean decision walk** — allocator placement decisions (striping,
  spills, ping-pong rotation) are genuinely sequential, so a slim Python
  pass makes exactly the reference decisions over local int state, but
  *records* its side effects (occupancy deltas, residency durations,
  per-event traffic classes) instead of mutating ``BankState``.
* **Deferred vectorized accounting** — traffic energies, per-bank
  occupancy integrals (∫occ·dt), residency maxima, and the per-op
  per-bank word tables are then reduced over the recorded arrays.
* **Vectorized closed-loop walk** — op pushback is a ``cumsum`` over
  per-op step lengths; per-bank busy intervals come out as merged,
  sorted float64 arrays (installed via ``BankState.set_busy_arrays``).
* **Vectorized pulse placement** — bank-granular idle-window queries
  become ``searchsorted`` over the busy arrays; row-granular packing
  walks gaps with per-gap ``cumsum`` cursor chains.

**Bit-identical by construction.**  Every float produced here replays
the reference backend's arithmetic operation-for-operation: ``cumsum``
is a sequential left fold (matching ``+=`` accumulation), ``rint``
matches ``round()`` (half-even), elementwise array ops match Python
float ops, and max/integer reductions are order-free and exact.  Where
the reference compares in a specific *form* (``s - t >= need`` vs
``t + need > hi`` in ``BankState.idle_window``) the same form is kept.
``tests/test_replay_backends.py`` fuzzes the equality; the golden suite
pins it across the Fig-24 / serving arms.

Not carried over: the vector allocator does not retain per-tensor
``Placement`` objects on ``Allocator.placements`` after the walk (the
reports never read them), and span recording (``repro.obs``) always
runs on the reference walk — ``trace.resolve_backend`` downgrades a
vector request with a logged warning when a recorder is attached.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.core import edram as ed
from repro.core.schedule import EVENT_KINDS
from repro.memory.allocator import Allocator
from repro.memory.banks import BankGeometry, BankState
from repro.memory.refresh import PulsePlacement, RefreshScheduler

# traffic class codes recorded per event by the decision walk
_NONE, _W_ON, _W_OFF, _R_ON, _R_OFF = 0, 1, 2, 3, 4


def _seqsum(a: np.ndarray) -> float:
    """Sequential left-fold sum — bit-identical to ``+=`` accumulation
    in array order (``np.cumsum`` is sequential; ``np.sum`` is pairwise
    and must not be used on floats here)."""
    return float(np.cumsum(a)[-1]) if a.size else 0.0


def _expand_csr(starts: np.ndarray, counts: np.ndarray):
    """Flat gather indices for variable-length spans: returns
    ``(rep, flat)`` where ``rep[j]`` is the source row of flat slot ``j``
    and ``flat[j]`` indexes the CSR value arrays."""
    total = int(counts.sum())
    rep = np.repeat(np.arange(len(counts)), counts)
    base = np.cumsum(counts) - counts
    offs = np.arange(total) - np.repeat(base, counts)
    return rep, starts[rep] + offs


class LazyOpTable:
    """Dict-compatible per-op per-bank word table, materialized on first
    access (the vector timeline path reads the sparse arrays directly
    and never pays for the dict)."""

    def __init__(self, builder):
        self._builder = builder
        self._d: Optional[dict] = None

    def _mat(self) -> dict:
        if self._d is None:
            self._d = self._builder()
            self._builder = None
        return self._d

    def get(self, key, default=None):
        return self._mat().get(key, default)

    def items(self):
        return self._mat().items()

    def keys(self):
        return self._mat().keys()

    def values(self):
        return self._mat().values()

    def __getitem__(self, key):
        return self._mat()[key]

    def __iter__(self):
        return iter(self._mat())

    def __len__(self):
        return len(self._mat())

    def __bool__(self):
        return bool(self._mat())

    def __contains__(self, key):
        return key in self._mat()

    def __eq__(self, other):
        if isinstance(other, LazyOpTable):
            other = other._mat()
        return self._mat() == other


@dataclasses.dataclass
class VectorState:
    """Sparse per-(op, bank) word tables + op interning, attached to a
    vector-built ``ReplayCore`` (``core.vector``) for the vectorized
    closed-loop walk."""
    n_banks: int
    op_index: dict                 # op name -> op id
    # sorted unique keys (op_id * n_banks + bank) and summed words
    r_keys: np.ndarray
    r_words: np.ndarray
    w_keys: np.ndarray
    w_words: np.ndarray


def _op_table_builder(keys: np.ndarray, words: np.ndarray,
                      first: np.ndarray, op_names: list, n_banks: int):
    """Materialize the reference backend's insertion-ordered
    ``{op: {bank: words}}`` dict: (op, bank) pairs enter in first-touch
    order, which reproduces both dict levels' key order exactly."""
    def build() -> dict:
        table: dict = {}
        order = np.argsort(first, kind="stable")
        ops = (keys // n_banks)[order].tolist()
        banks = (keys % n_banks)[order].tolist()
        vals = words[order].tolist()
        for op_id, bank, w in zip(ops, banks, vals):
            table.setdefault(op_names[op_id], {})[bank] = w
        return table
    return build


def replay_core_vector(events: Sequence, cfg, *, temp_c: float,
                       duration_s: float,
                       refresh_policy: str = "selective",
                       alloc_policy: str = "pingpong",
                       freq_hz: float = 500e6,
                       sample_scale: float = 1.0,
                       refresh_guard: float = 1.0,
                       retention_s: Optional[float] = None,
                       granularity: str = "bank",
                       reads_restore: bool = False):
    """Vector-backend twin of :func:`repro.memory.trace.replay_core` —
    same contract, bit-identical ``ReplayCore``; the returned core
    additionally carries ``core.vector`` (a :class:`VectorState`)."""
    from repro.memory import trace as mtr

    geom = BankGeometry.from_edram(cfg)
    sched = RefreshScheduler(refresh_policy, temp_c, guard=refresh_guard,
                             retention_s=retention_s,
                             granularity=granularity)
    alloc = Allocator(geom, policy=alloc_policy,
                      retention_s=sched.retention_s)
    n_banks = geom.n_banks
    words_for = geom.words_for
    word_bits = geom.word_bits

    # -- intern the event stream into parallel lists ---------------------
    n_ev = len(events)
    kinds: list = [None] * n_ev
    tids = [0] * n_ev
    opids = [0] * n_ev
    times = [0.0] * n_ev
    bits_l = [0.0] * n_ev
    buffered = [False] * n_ev
    t_index: dict = {}
    t_names: list = []
    op_index: dict = {}
    op_names: list = []
    for i, ev in enumerate(events):
        k = ev.kind
        if k not in EVENT_KINDS:
            raise ValueError(f"unknown trace event kind {k!r}")
        kinds[i] = k
        t = t_index.get(ev.tensor)
        if t is None:
            t = t_index[ev.tensor] = len(t_names)
            t_names.append(ev.tensor)
        tids[i] = t
        o = op_index.get(ev.op)
        if o is None:
            o = op_index[ev.op] = len(op_names)
            op_names.append(ev.op)
        opids[i] = o
        times[i] = ev.time
        bits_l[i] = ev.bits
        buffered[i] = ev.buffered
    n_t = len(t_names)

    # -- prepass 1: expected residency window per tensor ------------------
    first_seen = [None] * n_t
    win = [0.0] * n_t
    haswin = [False] * n_t
    for i in range(n_ev):
        k = kinds[i]
        t = tids[i]
        if k in ("alloc", "write"):
            if first_seen[t] is None:
                first_seen[t] = times[i]
        elif k in ("free", "evict") and first_seen[t] is not None:
            w = times[i] - first_seen[t]
            first_seen[t] = None
            win[t] = max(win[t], w) if haswin[t] else max(0.0, w)
            haswin[t] = True
    for t in range(n_t):
        if first_seen[t] is not None:
            w = duration_s - first_seen[t]
            win[t] = max(win[t], w) if haswin[t] else max(0.0, w)
            haswin[t] = True

    # -- prepass 2: peak streamed (non-buffered) working set --------------
    live_w = [0] * n_t
    live = [False] * n_t
    transient_peak = cur_w = 0
    # the reference main walk multiplies by the reciprocal; this prepass
    # divides — keep each form (they can differ in the last ulp)
    inv_scale = 1.0 / sample_scale
    for i in range(n_ev):
        if buffered[i]:
            continue
        k = kinds[i]
        t = tids[i]
        if k in ("alloc", "write"):
            if not live[t]:
                w = words_for(bits_l[i] / sample_scale)
                live[t] = True
                live_w[t] = w
                cur_w += w
                if cur_w > transient_peak:
                    transient_peak = cur_w
        elif k in ("free", "evict") and live[t]:
            live[t] = False
            cur_w -= live_w[t]

    # -- decision walk ----------------------------------------------------
    # Makes the reference allocator's placement decisions over local int
    # state; bank-side effects are recorded, not applied.
    lifetime = alloc_policy == "lifetime"
    retention = sched.retention_s
    words_per_bank = geom.words_per_bank
    free_w = [words_per_bank] * n_banks
    total_free = words_per_bank * n_banks
    bank_ids = list(range(n_banks))
    # ping-pong visit orders, precomputed per rotation
    rotations = [bank_ids[r:] + bank_ids[:r] for r in range(n_banks)]
    resident: list = [set() for _ in range(n_banks)] if lifetime else None

    placed_pid = [-1] * n_t        # current placement id per tensor
    pid_banks: list = []           # tuple of bank indices per pid
    pid_words: list = []           # tuple of span words per pid
    pid_sumw: list = []            # span words total (int)
    pid_write_t: list = []         # residency write time (s)
    pid_scale: list = []           # residency lifetime scale
    pid_expected: list = []        # expected lifetime (s) or None

    occ_bank: list = []            # occupancy delta records, walk order
    occ_time: list = []
    occ_delta: list = []
    res_pid: list = []             # residency-duration records
    res_dur: list = []
    ev_class = [0] * n_ev          # traffic class per event
    ev_pid = [-1] * n_ev
    spill_bits_l: list = []        # scaled bits per spill, walk order
    spilled: list = []
    evicted: list = []
    transient_now = 0
    next_bank = 0

    def _place(tid: int, bits: float, now: float, expected, lscale: float,
               reserve: int) -> int:
        nonlocal total_free, next_bank
        need = words_for(bits)
        if alloc_policy == "pingpong":
            tiers = [rotations[next_bank]]
        elif alloc_policy == "first_fit":
            tiers = [bank_ids]
        else:
            short = (retention is None or expected is None
                     or expected < retention)
            match_t: list = []
            other: list = []
            empty: list = []
            for b in range(n_banks):
                res = resident[b]
                if not res:
                    empty.append(b)
                    continue
                bank_short = all(
                    pid_expected[placed_pid[t]] is None
                    or retention is None
                    or pid_expected[placed_pid[t]] < retention
                    for t in res)
                (match_t if bank_short == short else other).append(b)
            tiers = [match_t, empty, other]
        pid = len(pid_banks)
        if need > total_free - max(0, reserve):
            spill_bits_l.append(bits)
            spilled.append(t_names[tid])
            pid_banks.append(())
            pid_words.append(())
            pid_sumw.append(0)
            pid_write_t.append(now)
            pid_scale.append(lscale)
            pid_expected.append(expected)
            return pid
        long_lived = (lifetime and retention is not None
                      and expected is not None and expected >= retention)
        takes: dict = {}
        remaining = need
        for tier in tiers:
            if remaining == 0:
                break
            if alloc_policy == "first_fit" or long_lived:
                for b in tier:
                    if remaining == 0:
                        break
                    fw = free_w[b]
                    take = fw if fw < remaining else remaining
                    if take:
                        takes[b] = take
                        remaining -= take
            else:
                while remaining > 0:
                    active = [b for b in tier
                              if free_w[b] > takes.get(b, 0)]
                    if not active:
                        break
                    share = -(-remaining // len(active))
                    for b in active:
                        room = free_w[b] - takes.get(b, 0)
                        take = share if share < room else room
                        if take > remaining:
                            take = remaining
                        if take:
                            takes[b] = takes.get(b, 0) + take
                            remaining -= take
                        if remaining == 0:
                            break
        spans_b: list = []
        spans_w: list = []
        for tier in tiers:
            for b in tier:
                w = takes.get(b)
                if w:
                    spans_b.append(b)
                    spans_w.append(w)
                    free_w[b] -= w
                    occ_bank.append(b)
                    occ_time.append(now)
                    occ_delta.append(w)
                    if lifetime:
                        resident[b].add(tid)
        if alloc_policy == "pingpong" and spans_b:
            next_bank = (spans_b[0] + 1) % n_banks
        sumw = need - remaining
        total_free -= sumw
        pid_banks.append(tuple(spans_b))
        pid_words.append(tuple(spans_w))
        pid_sumw.append(sumw)
        pid_write_t.append(now)
        pid_scale.append(lscale)
        pid_expected.append(expected)
        return pid

    for i in range(n_ev):
        k = kinds[i]
        t = tids[i]
        tm = times[i]
        buf = buffered[i]
        scale = 1.0 if buf else inv_scale
        if k in ("alloc", "write"):
            pid = placed_pid[t]
            if pid >= 0:
                if pid_banks[pid]:       # off-chip placements have no
                    res_pid.append(pid)  # residency clock to restart
                    res_dur.append((tm - pid_write_t[pid]) * pid_scale[pid])
                    pid_write_t[pid] = tm
            else:
                w = win[t] if haswin[t] else None
                reserve = (max(0, transient_peak - transient_now)
                           if buf else 0)
                pid = _place(t, bits_l[i] * scale, tm,
                             None if w is None else w * scale, scale,
                             reserve)
                placed_pid[t] = pid
                if not buf and pid_banks[pid]:
                    transient_now += pid_sumw[pid]
            if k == "write":
                if pid_banks[pid]:
                    ev_class[i] = _W_ON
                    ev_pid[i] = pid
                else:
                    ev_class[i] = _W_OFF
        elif k == "read":
            pid = placed_pid[t]
            if pid < 0 or not pid_banks[pid]:
                ev_class[i] = _R_OFF
            else:
                ev_class[i] = _R_ON
                ev_pid[i] = pid
                if reads_restore:
                    res_pid.append(pid)
                    res_dur.append((tm - pid_write_t[pid]) * pid_scale[pid])
                    pid_write_t[pid] = tm
        else:                            # free | evict
            pid = placed_pid[t]
            if not buf and pid >= 0 and pid_banks[pid]:
                transient_now -= pid_sumw[pid]
            if k == "evict" and pid >= 0:
                evicted.append(t_names[t])
            if pid >= 0:
                if pid_banks[pid]:
                    res_pid.append(pid)
                    res_dur.append((tm - pid_write_t[pid]) * pid_scale[pid])
                    for b, w in zip(pid_banks[pid], pid_words[pid]):
                        free_w[b] += w
                        occ_bank.append(b)
                        occ_time.append(tm)
                        occ_delta.append(-w)
                        if lifetime:
                            resident[b].discard(t)
                    total_free += pid_sumw[pid]
                placed_pid[t] = -1

    # finalize: still-placed tensors live until the trace end
    for t in range(n_t):
        pid = placed_pid[t]
        if pid >= 0 and pid_banks[pid]:
            res_pid.append(pid)
            res_dur.append((duration_s - pid_write_t[pid]) * pid_scale[pid])

    # -- deferred vectorized accounting ----------------------------------
    bits_a = np.asarray(bits_l, dtype=np.float64)
    times_a = np.asarray(times, dtype=np.float64)
    cls = np.asarray(ev_class, dtype=np.int8)
    pids_a = np.asarray(ev_pid, dtype=np.int64)
    opids_a = np.asarray(opids, dtype=np.int64)

    # traffic energies: zero contributions are exact identities under the
    # sequential fold, so masking via where() preserves the reference
    # accumulation order
    w_on = cls == _W_ON
    r_on = cls == _R_ON
    off = (cls == _W_OFF) | (cls == _R_OFF)
    zeros = np.zeros(n_ev)
    write_j = _seqsum(np.where(
        w_on, bits_a * cfg.write_pj_per_bit * 1e-12, zeros))
    read_pj = cfg.read_pj_per_bit
    if reads_restore:
        read_pj = read_pj + cfg.refresh_restore_pj
    read_j = _seqsum(np.where(r_on, bits_a * read_pj * 1e-12, zeros))
    restore_j = _seqsum(np.where(
        r_on, bits_a * cfg.refresh_restore_pj * 1e-12, zeros)) \
        if reads_restore else 0.0
    offchip_j = _seqsum(np.where(
        off, bits_a * cfg.dram_pj_per_bit * 1e-12, zeros))
    offchip_bits = _seqsum(np.where(off, bits_a, zeros))

    # pid span CSR
    n_pid = len(pid_banks)
    span_counts = np.asarray([len(b) for b in pid_banks], dtype=np.int64)
    span_indptr = np.concatenate(([0], np.cumsum(span_counts)))
    span_bank = np.asarray(
        [b for bs in pid_banks for b in bs], dtype=np.int64)
    span_words = np.asarray(
        [w for ws in pid_words for w in ws], dtype=np.int64)
    pid_sumw_a = np.asarray(pid_sumw, dtype=np.int64)

    def _per_bank_traffic(mask: np.ndarray) -> np.ndarray:
        """Per-bank ``bits / n_spans`` traffic sums, bank-major with the
        reference event order inside each bank (np.bincount accumulates
        sequentially in input order)."""
        idx = np.flatnonzero(mask)
        if not idx.size:
            return np.zeros(n_banks)
        p = pids_a[idx]
        counts = span_counts[p]
        rep, flat = _expand_csr(span_indptr[p], counts)
        contrib = (bits_a[idx] / np.maximum(1, counts))[rep]
        return np.bincount(span_bank[flat], weights=contrib,
                           minlength=n_banks)

    bank_write_bits = _per_bank_traffic(w_on)
    bank_read_bits = _per_bank_traffic(r_on)

    # per-(op, bank) word tables (sparse, summed; int-exact)
    def _op_table(mask: np.ndarray):
        idx = np.flatnonzero(mask)
        if not idx.size:
            z = np.zeros(0, dtype=np.int64)
            return z, z, z
        p = pids_a[idx]
        counts = span_counts[p]
        rep, flat = _expand_csr(span_indptr[p], counts)
        eb = bits_a[idx]
        words_ev = np.where(
            eb > 0,
            np.maximum(1, np.ceil(eb / word_bits)).astype(np.int64),
            0).astype(np.int64)
        span_total = np.maximum(1, pid_sumw_a[p])
        per_span = np.maximum(1, np.rint(
            (words_ev[rep] * span_words[flat])
            / span_total[rep])).astype(np.int64)
        keys = opids_a[idx][rep] * n_banks + span_bank[flat]
        uk, first, inv = np.unique(keys, return_index=True,
                                   return_inverse=True)
        sums = np.bincount(inv, weights=per_span.astype(
            np.float64)).astype(np.int64)
        return uk, sums, first

    w_keys, w_words, w_first = _op_table(w_on)
    r_keys, r_words, r_first = _op_table(r_on)

    # per-bank occupancy integral / peak / residency maxima
    occ_bank_a = np.asarray(occ_bank, dtype=np.int64)
    occ_time_a = np.asarray(occ_time, dtype=np.float64)
    occ_delta_a = np.asarray(occ_delta, dtype=np.int64)
    order = np.argsort(occ_bank_a, kind="stable")
    ob, ot, od = occ_bank_a[order], occ_time_a[order], occ_delta_a[order]
    seg = np.searchsorted(ob, np.arange(n_banks + 1))
    occ_bit_s = [0.0] * n_banks
    peak_words = [0] * n_banks
    used_final = [0] * n_banks
    last_t = [0.0] * n_banks
    for b in range(n_banks):
        lo, hi = int(seg[b]), int(seg[b + 1])
        t_b = ot[lo:hi]
        d_b = od[lo:hi]
        used_after = np.cumsum(d_b)
        used_before = used_after - d_b
        # the reference advance() only moves time forward: its _last_t
        # chain is the running max of the event times (from 0.0)
        run = np.maximum.accumulate(np.concatenate(([0.0], t_b)))
        dt = t_b - run[:-1]
        contrib = np.where(
            dt > 0, (used_before * word_bits).astype(np.float64) * dt, 0.0)
        total = _seqsum(contrib)
        # finalize(duration_s): one last advance at the trace end
        end_last = float(run[-1])
        used_end = int(used_after[-1]) if hi > lo else 0
        if duration_s > end_last:
            total = total + used_end * word_bits * (duration_s - end_last)
            end_last = duration_s
        occ_bit_s[b] = total
        used_final[b] = used_end
        last_t[b] = end_last
        alloc_mask = d_b > 0
        peak_words[b] = int(used_after[alloc_mask].max()) \
            if alloc_mask.any() else 0

    max_resident = np.zeros(n_banks)
    if res_pid:
        rp = np.asarray(res_pid, dtype=np.int64)
        rd = np.asarray(res_dur, dtype=np.float64)
        counts = span_counts[rp]
        rep, flat = _expand_csr(span_indptr[rp], counts)
        np.maximum.at(max_resident, span_bank[flat], rd[rep])

    # -- populate the real Allocator/BankState objects --------------------
    alloc.spill_bits = float(sum(spill_bits_l))
    alloc.spilled = spilled
    alloc.evicted = evicted
    alloc._next_bank = next_bank
    for b in alloc.banks:
        i = b.index
        b.read_bits = float(bank_read_bits[i])
        b.write_bits = float(bank_write_bits[i])
        b.peak_words = peak_words[i]
        b.used_words = used_final[i]
        b.max_resident_s = float(max_resident[i])
        b.occ_bit_s = float(occ_bit_s[i])
        b._last_t = last_t[i]

    state = VectorState(n_banks=n_banks, op_index=op_index,
                        r_keys=r_keys, r_words=r_words,
                        w_keys=w_keys, w_words=w_words)
    return mtr.ReplayCore(
        cfg=cfg, geom=geom, sched=sched, alloc=alloc,
        refresh_policy=refresh_policy, alloc_policy=alloc_policy,
        temp_c=temp_c, duration_s=duration_s, freq_hz=freq_hz,
        read_j=read_j, write_j=write_j, offchip_j=offchip_j,
        offchip_bits=offchip_bits,
        op_read_words=LazyOpTable(_op_table_builder(
            r_keys, r_words, r_first, op_names, n_banks)),
        op_write_words=LazyOpTable(_op_table_builder(
            w_keys, w_words, w_first, op_names, n_banks)),
        restore_j=restore_j, vector=state)


# -- closed-loop walk --------------------------------------------------


def closed_loop_walk_vector(core, op_schedule) -> float:
    """Vector twin of :func:`repro.sim.timeline.closed_loop_walk`: the
    op pushback chain is a ``cumsum`` over per-op steps; per-bank busy
    intervals are merged into sorted arrays and installed on each
    ``BankState`` via :meth:`set_busy_arrays`.  Returns the makespan."""
    st: VectorState = core.vector
    n_banks = st.n_banks
    freq_hz = core.freq_hz
    banks = core.alloc.banks

    n = len(op_schedule)
    starts0 = np.fromiter((s for _, s, _ in op_schedule), np.float64, n)
    ends0 = np.fromiter((e for _, _, e in op_schedule), np.float64, n)
    dur = ends0 - starts0
    keep = dur > 0.0
    if not keep.any():
        for b in banks:
            b.set_busy_arrays(np.zeros(0), np.zeros(0))
        return 0.0
    op_ids = np.fromiter(
        (st.op_index.get(name, -1) for name, _, _ in op_schedule),
        np.int64, n)[keep]
    dur = dur[keep]
    n_ops = len(st.op_index)

    # combined per-(op, bank) word max: the reference occupies the read
    # and write services as two same-start intervals whose merge keeps
    # the longer — max(fl(w_r/f), fl(w_w/f)) == fl(max(w_r, w_w)/f)
    allk = np.concatenate((st.r_keys, st.w_keys))
    allw = np.concatenate((st.r_words, st.w_words))
    uk, inv = np.unique(allk, return_inverse=True)
    wmax = np.zeros(len(uk), dtype=np.int64)
    np.maximum.at(wmax, inv, allw)

    # per-op slowest port (words): indexes into an n_ops+1 array so the
    # unknown-op sentinel -1 reads the trailing zero
    op_peak = np.zeros(n_ops + 1, dtype=np.int64)
    if len(uk):
        np.maximum.at(op_peak, uk // n_banks, wmax)
        op_peak[n_ops] = 0
    peak_words = op_peak[op_ids]
    busy_max = peak_words / freq_hz if freq_hz > 0 \
        else np.zeros(len(peak_words))

    steps = np.maximum(dur, busy_max)
    t_ends = np.cumsum(steps)
    op_starts = np.concatenate(([0.0], t_ends[:-1]))
    makespan = float(t_ends[-1])

    # per-(scheduled op, bank) busy intervals
    if len(uk) and freq_hz > 0:
        key_lo = np.searchsorted(uk, op_ids * n_banks)
        key_hi = np.searchsorted(uk, (op_ids + 1) * n_banks)
        counts = key_hi - key_lo
        rep, flat = _expand_csr(key_lo, counts)
        words_f = wmax[flat]
        nz = words_f > 0
        rep, flat, words_f = rep[nz], flat[nz], words_f[nz]
        iv_bank = uk[flat] % n_banks
        iv_start = op_starts[rep]
        iv_end = iv_start + words_f / freq_hz
    else:
        iv_bank = np.zeros(0, dtype=np.int64)
        iv_start = iv_end = np.zeros(0)

    order = np.argsort(iv_bank, kind="stable")
    ib, istart, iend = iv_bank[order], iv_start[order], iv_end[order]
    seg = np.searchsorted(ib, np.arange(n_banks + 1))
    for b in banks:
        lo, hi = int(seg[b.index]), int(seg[b.index + 1])
        s_b, e_b = istart[lo:hi], iend[lo:hi]
        if not len(s_b):
            b.set_busy_arrays(s_b, e_b)
            continue
        # merge: an interval starting at or before the running max end
        # joins the previous group (occupy_port's `start <= last end`)
        run_end = np.maximum.accumulate(e_b)
        new_grp = np.empty(len(s_b), dtype=bool)
        new_grp[0] = True
        new_grp[1:] = s_b[1:] > run_end[:-1]
        heads = np.flatnonzero(new_grp)
        b.set_busy_arrays(s_b[heads], np.maximum.reduceat(e_b, heads))
    return makespan


# -- pulse placement ---------------------------------------------------


@dataclasses.dataclass
class BankPulses:
    """One bank's pulse placements as parallel arrays (the vector form
    of ``list[PulsePlacement]``); placement order matches the reference
    scheduler (ticks ascending; rows then the preempting run)."""
    bank: int
    tick: np.ndarray
    deadline: np.ndarray
    start: np.ndarray
    hidden: np.ndarray
    stall: np.ndarray
    row: np.ndarray
    words: np.ndarray
    rows: np.ndarray

    @property
    def count(self) -> int:
        return int(self.rows.sum())

    @property
    def hidden_count(self) -> int:
        return int(self.rows[self.hidden].sum())

    @property
    def stall_s(self) -> float:
        # left fold in placement order (hidden zeros are exact
        # identities under addition)
        return sum(self.stall.tolist())

    def to_placements(self) -> list:
        """Materialize the exact ``PulsePlacement`` list the reference
        ``place_pulses`` would return."""
        return [PulsePlacement(bank=self.bank, index=k, deadline_s=d,
                               start_s=s, hidden=h, stall_s=st, row=r,
                               words=w, rows=rs)
                for k, d, s, h, st, r, w, rs in zip(
                    self.tick.tolist(), self.deadline.tolist(),
                    self.start.tolist(), self.hidden.tolist(),
                    self.stall.tolist(), self.row.tolist(),
                    self.words.tolist(), self.rows.tolist())]


def _empty_pulses(bank_idx: int) -> BankPulses:
    zi = np.zeros(0, dtype=np.int64)
    zf = np.zeros(0)
    return BankPulses(bank=bank_idx, tick=zi, deadline=zf, start=zf,
                      hidden=np.zeros(0, dtype=bool), stall=zf, row=zi,
                      words=zi, rows=zi)


def place_pulses_vector(sched: RefreshScheduler, bank: BankState,
                        duration_s: float, freq_hz: float) -> BankPulses:
    """Vector twin of :meth:`RefreshScheduler.place_pulses` over the
    bank's busy arrays — bit-identical placements (fuzz-pinned)."""
    if duration_s <= 0 or not math.isfinite(sched.interval_s):
        return _empty_pulses(bank.index)
    chunks = sched.pulse_chunks(bank)
    if not chunks:
        return _empty_pulses(bank.index)
    from repro.memory.banks import port_service_s
    widths = [port_service_s(w, freq_hz) for w in chunks]
    interval = sched.interval_s
    ticks = math.ceil(duration_s / interval)
    ks = np.arange(1, ticks + 1, dtype=np.int64)
    lo = (ks - 1) * interval
    deadline = np.minimum(ks * interval, duration_s)
    s_arr, e_arr = bank.busy_arrays()

    if sched.granularity == "bank":
        return _place_bank(sched, bank.index, chunks[0], widths[0], ks,
                           lo, deadline, s_arr, e_arr)
    return _place_rows(bank.index, chunks, widths, ks, lo, deadline,
                       s_arr, e_arr)


def _place_bank(sched, bank_idx, words, pulse_s, ks, lo, deadline,
                s_arr, e_arr) -> BankPulses:
    ticks = len(ks)
    n = len(s_arr)
    if pulse_s <= 0.0:
        # idle_window: need_s <= 0 fits at lo whenever deadline >= lo
        start = lo
        hidden = deadline >= lo
    else:
        # replicate idle_window() over all ticks at once; comparison
        # forms are kept verbatim (`s - t >= need` vs `t + need > hi`)
        none0 = lo + pulse_s > deadline
        j0 = np.searchsorted(e_arr, lo, side="right")
        s_pad = np.concatenate((s_arr, [np.inf]))
        # gap at the tick's lo fits, or the first busy starts past hi
        at_lo = (s_pad[j0] >= deadline) | (s_pad[j0] - lo >= pulse_s)
        if n:
            # first post-busy gap that fits (tick-independent), walked
            # from j0; the run of e_j candidates ends at the first busy
            # starting past hi
            gapfit = np.empty(n, dtype=bool)
            gapfit[:-1] = (s_arr[1:] - e_arr[:-1]) >= pulse_s
            gapfit[-1] = True
            idx = np.arange(n)
            nf = np.minimum.accumulate(
                np.where(gapfit, idx, n)[::-1])[::-1]
            j0c = np.minimum(j0, n - 1)
            jg = nf[j0c]
            jhi = np.searchsorted(s_arr, deadline, side="left")
            j_ret = np.minimum(jg, np.maximum(j0c, jhi - 1))
            cand = e_arr[j_ret]
            found_after = cand + pulse_s <= deadline
        else:
            cand = lo
            found_after = np.zeros(ticks, dtype=bool)
        hidden = ~none0 & (at_lo | found_after)
        start = np.where(at_lo, lo, cand)
    out_start = np.where(hidden, start, deadline)
    stall = np.where(hidden, 0.0, pulse_s)
    return BankPulses(
        bank=bank_idx, tick=ks, deadline=deadline, start=out_start,
        hidden=hidden, stall=stall,
        row=np.zeros(ticks, dtype=np.int64),
        words=np.full(ticks, words, dtype=np.int64),
        rows=np.ones(ticks, dtype=np.int64))


def _place_rows(bank_idx, chunks, widths, ks, lo, deadline,
                s_arr, e_arr) -> BankPulses:
    """Row-granular packing: per tick, rows pack front-to-back into the
    tick's idle gaps; the cursor chain inside one gap is a ``cumsum``
    starting at the gap's left edge (exactly the reference's repeated
    ``cursor += pulse_s``)."""
    ticks = len(ks)
    n_rows = len(chunks)
    widths_a = np.asarray(widths)
    chunks_a = np.asarray(chunks, dtype=np.int64)

    # per-tick gap table from the global busy complement: clipping picks
    # max/min of existing floats, so gap edges match idle_gaps() exactly
    g_start = np.concatenate(([-np.inf], e_arr))
    g_end = np.concatenate((s_arr, [np.inf]))
    g_lo = np.searchsorted(g_end, lo, side="right")
    g_hi = np.searchsorted(g_start, deadline, side="left")
    counts = np.maximum(0, g_hi - g_lo)
    # a zero-width leading gap (busy starting exactly at lo) is skipped
    # by idle_gaps' strict `s > t`; it can only be the first gap
    first_end = np.minimum(deadline, g_end[np.minimum(g_lo, len(g_end) - 1)])
    first_start = np.maximum(lo, g_start[np.minimum(g_lo, len(g_end) - 1)])
    g_lo = g_lo + ((counts > 0) & (first_end <= first_start))

    out_tick: list = []
    out_deadline: list = []
    out_start: list = []
    out_stall: list = []
    out_row: list = []
    out_words: list = []
    out_rows: list = []
    out_hidden: list = []
    gs_l = g_start.tolist()
    ge_l = g_end.tolist()
    lo_l = lo.tolist()
    dl_l = deadline.tolist()
    g_lo_l = g_lo.tolist()
    g_hi_l = g_hi.tolist()
    widths_l = widths
    chunks_l = chunks

    for ti in range(ticks):
        tick_lo = lo_l[ti]
        hi = dl_l[ti]
        r = 0
        for g in range(g_lo_l[ti], g_hi_l[ti]):
            if r >= n_rows:
                break
            c0 = gs_l[g]
            if c0 < tick_lo:
                c0 = tick_lo
            gend = ge_l[g]
            if gend > hi:
                gend = hi
            if gend <= c0:
                continue
            w_rem = widths_a[r:]
            chain = np.cumsum(np.concatenate(([c0], w_rem)))
            fit = (gend - chain[:-1]) >= w_rem
            k = int(np.argmin(fit)) if not fit.all() else len(fit)
            if k:
                out_tick.append(np.full(k, ks[ti]))
                out_deadline.append(np.full(k, hi))
                out_start.append(chain[:k])
                out_stall.append(np.zeros(k))
                out_row.append(np.arange(r, r + k))
                out_words.append(chunks_a[r:r + k])
                out_rows.append(np.ones(k, dtype=np.int64))
                out_hidden.append(np.ones(k, dtype=bool))
                r += k
        if r < n_rows:
            # gaps exhausted: this row and every later one preempt, as
            # one aggregated run (left-fold sums match the reference's
            # sum(widths[r:]) / sum(chunks[r:]))
            out_tick.append(np.asarray([ks[ti]]))
            out_deadline.append(np.asarray([hi]))
            out_start.append(np.asarray([hi]))
            out_stall.append(np.asarray([sum(widths_l[r:])]))
            out_row.append(np.asarray([r]))
            out_words.append(np.asarray([sum(chunks_l[r:])],
                                        dtype=np.int64))
            out_rows.append(np.asarray([n_rows - r], dtype=np.int64))
            out_hidden.append(np.asarray([False]))

    if not out_tick:
        return _empty_pulses(bank_idx)
    return BankPulses(
        bank=bank_idx,
        tick=np.concatenate(out_tick).astype(np.int64),
        deadline=np.concatenate(out_deadline),
        start=np.concatenate(out_start),
        hidden=np.concatenate(out_hidden),
        stall=np.concatenate(out_stall),
        row=np.concatenate(out_row).astype(np.int64),
        words=np.concatenate(out_words),
        rows=np.concatenate(out_rows))


def place_all_pulses_vector(core, makespan: float) -> dict:
    """Pulse placements for every bank the policy refreshes — the vector
    twin of the dict comprehension in ``replay_timeline``; returns
    ``{bank index: BankPulses}``."""
    return {
        b.index: place_pulses_vector(core.sched, b, makespan, core.freq_hz)
        for b in core.alloc.banks if core.sched.would_refresh(b)}
