"""Bank/word geometry and per-bank occupancy state (CAMEL §V-C/D).

The eDRAM macro is organized as ``n_banks`` banks of 58-bit words — one
word per 2D BFP group (4-bit shared exponent + 9 × 6-bit mantissas).  Each
bank has one read and one write port moving one word per cycle, so tensors
striped across more banks see higher aggregate bandwidth; two tensors
resident in the same bank contend for its ports (the bank-conflict model
``trace.replay`` charges stalls from).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class BankGeometry:
    """Word/bank shape derived from an ``EDRAMConfig``.

    ``rows_per_bank`` is the wordline count — the silicon refresh
    granularity: one refresh pulse senses and restores one row
    (``words_per_row`` words).  ``rows_per_bank=0`` (the default for
    hand-built geometries) means "one row spans the whole bank", which
    makes a row pulse degenerate to the bank-granular pulse.
    """
    word_bits: int
    words_per_bank: int
    n_banks: int
    rows_per_bank: int = 0

    @classmethod
    def from_edram(cls, cfg) -> "BankGeometry":
        # bank_kb is authoritative for capacity (matches
        # edram.capacity_bits); the word count per bank follows from the
        # 58-bit BFP word size.  EDRAMConfig.words_per_bank is the paper's
        # *row* count (a row holds several words) — it sets refresh
        # granularity in silicon, not storage capacity, so it enters the
        # geometry as rows_per_bank, not as capacity.
        words = int(cfg.bank_kb * 1024 * 8 // cfg.word_bits)
        return cls(word_bits=cfg.word_bits, words_per_bank=words,
                   n_banks=cfg.n_banks, rows_per_bank=cfg.words_per_bank)

    @property
    def words_per_row(self) -> int:
        """Words one wordline holds — the row-refresh transfer unit."""
        if self.rows_per_bank <= 0:
            return self.words_per_bank
        return max(1, math.ceil(self.words_per_bank / self.rows_per_bank))

    def rows_for(self, words: int) -> int:
        """Rows needed to hold ``words`` contiguously (ceil)."""
        return max(0, math.ceil(words / self.words_per_row))

    @property
    def bank_bits(self) -> int:
        return self.word_bits * self.words_per_bank

    @property
    def total_bits(self) -> int:
        return self.bank_bits * self.n_banks

    @property
    def total_words(self) -> int:
        return self.words_per_bank * self.n_banks

    def words_for(self, bits: float) -> int:
        """Words needed to hold ``bits`` (ceil — a word is the unit)."""
        return max(1, math.ceil(bits / self.word_bits)) if bits > 0 else 0


def port_service_s(words: int, freq_hz: float) -> float:
    """Time for one bank port to move ``words`` (one word/cycle)."""
    return words / freq_hz if freq_hz > 0 else 0.0


@dataclasses.dataclass
class _Residency:
    words: int
    write_t: float
    # residency-to-data-lifetime scale: the weight-stationary dataflow
    # streams the batch sample-by-sample, so a transient tensor resident
    # for a whole-batch op window holds each sample's value only 1/batch
    # of that time (scale = 1/batch); a whole-iteration buffered tensor
    # (the FR baseline's activation stash) really holds its data the full
    # window (scale = 1).
    scale: float = 1.0


class BankState:
    """Occupancy, residency lifetimes, and traffic counters for one bank.

    Besides the occupancy/refresh bookkeeping, a bank records the *port
    busy intervals* the closed-loop timeline model feeds it
    (:meth:`occupy_port`): time spans during which one of its ports is
    moving words for an op.  :meth:`idle_window` answers the refresh
    scheduler's placement query — "is there a gap of ``need_s`` seconds
    before this pulse's deadline?" — which is what lets refresh hide
    under compute instead of serializing against it.
    """

    def __init__(self, index: int, geometry: BankGeometry):
        self.index = index
        self.geometry = geometry
        self.resident: dict[str, _Residency] = {}
        self.used_words = 0
        self.peak_words = 0
        # traffic (bits) and port-busy time (s) for the conflict model
        self.read_bits = 0.0
        self.write_bits = 0.0
        self.stall_s = 0.0
        # refresh bookkeeping
        self.max_resident_s = 0.0        # longest residency (scaled to data
        #                                  lifetime, see _Residency.scale)
        self.refresh_count = 0
        self.refresh_bits = 0.0
        self.refresh_hidden = 0          # pulses placed into idle windows
        # ∫ occupied_bits dt — refresh energy integrates this
        self.occ_bit_s = 0.0
        self._last_t = 0.0
        # port busy intervals [(start_s, end_s), ...] recorded by the
        # timeline model's closed-loop walk; kept sorted and merged
        self._busy: list[tuple[float, float]] = []
        # vector-backend storage: sorted/merged float64 arrays standing
        # in for _busy (set_busy_arrays); None on the reference path
        self._busy_arrays = None
        # optional observability hook: called as (bank, now) after every
        # occupancy change (allocate/free).  The flight recorder
        # (repro.obs) samples its per-bank occupancy counter here; when
        # unset (the default) occupancy changes cost nothing extra.
        self.on_occupancy = None

    # -- port timeline (closed-loop timing model) ------------------------
    def occupy_port(self, start: float, end: float) -> None:
        """Record that a port of this bank is busy over ``[start, end)``
        seconds.  Calls must arrive with non-decreasing ``start`` (the
        timeline walk is time-ordered); overlapping or adjacent intervals
        are merged in place."""
        if end <= start:
            return
        if self._busy_arrays is not None:
            raise RuntimeError(
                "bank busy intervals are array-backed (vector replay); "
                "occupy_port is a reference-walk API")
        if self._busy and start <= self._busy[-1][1]:
            s, e = self._busy[-1]
            self._busy[-1] = (s, max(e, end))
        else:
            self._busy.append((start, end))

    def set_busy_arrays(self, starts, ends) -> None:
        """Install the merged port-busy spans as sorted float64 arrays
        (the vector backend's representation).  Every busy-interval query
        (``busy_s`` / ``busy_intervals`` / ``idle_window`` / ``idle_gaps``)
        reads through to them, element-for-element identical to the tuple
        list ``occupy_port`` would have built."""
        self._busy_arrays = (starts, ends)

    def busy_arrays(self):
        """The busy spans as a ``(starts, ends)`` float64 array pair —
        built on the fly when the bank was walked by the reference path."""
        import numpy as np
        if self._busy_arrays is not None:
            return self._busy_arrays
        starts = np.array([s for s, _ in self._busy], dtype=np.float64)
        ends = np.array([e for _, e in self._busy], dtype=np.float64)
        return starts, ends

    def _iter_busy(self):
        if self._busy_arrays is not None:
            starts, ends = self._busy_arrays
            return zip(starts.tolist(), ends.tolist())
        return iter(self._busy)

    @property
    def busy_s(self) -> float:
        """Total port-busy time (s) recorded by the timeline walk."""
        if self._busy_arrays is not None:
            import numpy as np
            starts, ends = self._busy_arrays
            if not len(starts):
                return 0
            # cumsum is a sequential left fold — bit-identical to the
            # reference generator sum over the tuple list
            return float(np.cumsum(ends - starts)[-1])
        return sum(e - s for s, e in self._busy)

    @property
    def busy_intervals(self) -> tuple:
        """The merged ``(start_s, end_s)`` port-busy spans, sorted."""
        return tuple(self._iter_busy())

    def idle_window(self, lo: float, hi: float,
                    need_s: float) -> float | None:
        """Earliest ``t`` in ``[lo, hi - need_s]`` such that
        ``[t, t + need_s]`` overlaps no recorded busy interval; ``None``
        when no such gap exists.  ``need_s <= 0`` trivially fits at
        ``lo``.  This is the refresh scheduler's idle-window query."""
        if need_s <= 0.0:
            return lo if hi >= lo else None
        if lo + need_s > hi:
            return None
        t = lo
        for s, e in self._iter_busy():
            if e <= t:
                continue
            if s >= hi:
                break
            if s - t >= need_s:
                return t
            t = max(t, e)
            if t + need_s > hi:
                return None
        return t if t + need_s <= hi else None

    def idle_gaps(self, lo: float, hi: float) -> list[tuple[float, float]]:
        """The maximal port-idle spans inside ``[lo, hi]``, in time order.
        This is the row-granular refresh scheduler's placement query: it
        packs one tick's row pulses into these gaps front-to-back, so the
        pulses can never overlap each other or a busy interval."""
        gaps: list[tuple[float, float]] = []
        if hi <= lo:
            return gaps
        t = lo
        for s, e in self._iter_busy():
            if e <= t:
                continue
            if s >= hi:
                break
            if s > t:
                gaps.append((t, s))
            t = max(t, e)
            if t >= hi:
                return gaps
        gaps.append((t, hi))
        return gaps

    @property
    def free_words(self) -> int:
        return self.geometry.words_per_bank - self.used_words

    @property
    def occupied_bits(self) -> float:
        return self.used_words * self.geometry.word_bits

    def advance(self, now: float) -> None:
        """Accumulate the occupancy integral up to ``now``."""
        if now > self._last_t:
            self.occ_bit_s += self.occupied_bits * (now - self._last_t)
            self._last_t = now

    def allocate(self, tensor: str, words: int, now: float,
                 scale: float = 1.0) -> None:
        if words > self.free_words:
            raise ValueError(
                f"bank {self.index}: {words} words > {self.free_words} free")
        self.advance(now)
        self.resident[tensor] = _Residency(words=words, write_t=now,
                                           scale=scale)
        self.used_words += words
        self.peak_words = max(self.peak_words, self.used_words)
        if self.on_occupancy is not None:
            self.on_occupancy(self, now)

    def rewrite(self, tensor: str, now: float) -> None:
        """In-place overwrite: residency lifetime restarts at ``now``."""
        r = self.resident[tensor]
        self.max_resident_s = max(self.max_resident_s,
                                  (now - r.write_t) * r.scale)
        r.write_t = now

    def touch(self, tensor: str, now: float) -> None:
        """Read-triggered restore (Kelle-style refresh skipping): an eDRAM
        read is destructive, so a read that writes the sensed value back
        resets the cell's decay clock exactly like a refresh pulse would.
        Residency bookkeeping is identical to :meth:`rewrite` — the bank's
        ``max_resident_s`` then measures the longest *inter-touch* gap, so
        the ``selective`` refresh policy only fires when some entry's next
        read misses the retention deadline."""
        self.rewrite(tensor, now)

    def free(self, tensor: str, now: float) -> float:
        """Release ``tensor``; returns its scaled residency duration."""
        r = self.resident.pop(tensor)
        self.advance(now)
        self.used_words -= r.words
        dur = (now - r.write_t) * r.scale
        self.max_resident_s = max(self.max_resident_s, dur)
        if self.on_occupancy is not None:
            self.on_occupancy(self, now)
        return dur

    def finalize(self, now: float) -> None:
        """Close the books at end of trace: still-resident tensors have
        lived until ``now`` (they survive into the next iteration)."""
        self.advance(now)
        for r in self.resident.values():
            self.max_resident_s = max(self.max_resident_s,
                                      (now - r.write_t) * r.scale)
