"""Serving steps: batched prefill and single-token decode (greedy/temperature).

``decode_32k`` / ``long_500k`` cells lower ``decode_step`` — one new token
against a KV/state cache of the shape's seq_len — per the assignment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.common import ModelConfig
from repro.models import layers as L


def make_prefill_step(entry, cfg: ModelConfig, *, max_len: int,
                      policy: L.Policy = L.Policy(),
                      cache_dtype=jnp.bfloat16, logits_mode: str = "all"):
    module = entry.module

    def prefill_step(params, tokens, frontend=None):
        kw = {} if frontend is None else {"frontend": frontend}
        out = module.prefill(params, cfg, tokens, max_len=max_len,
                             policy=policy, cache_dtype=cache_dtype,
                             logits_mode=logits_mode, **kw)
        next_logits = out["logits"][:, -1]
        return {"next_token_logits": next_logits, "cache": out["cache"]}

    return prefill_step


def make_decode_step(entry, cfg: ModelConfig, *,
                     policy: L.Policy = L.Policy(), greedy: bool = True,
                     temperature: float = 1.0):
    module = entry.module

    def decode_step(params, cache, tokens, rng=None):
        logits, new_cache = module.decode_step(params, cfg, tokens, cache,
                                               policy=policy)
        last = logits[:, -1]
        if greedy:
            nxt = jnp.argmax(last, axis=-1)
        else:
            nxt = jax.random.categorical(rng, last / temperature, axis=-1)
        return nxt[:, None].astype(jnp.int32), new_cache

    return decode_step
