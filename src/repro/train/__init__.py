"""repro.train"""
