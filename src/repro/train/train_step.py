"""Train steps: the paper's Duplex regime (frozen backbone + reversible
branch) as the first-class path, plus the full-finetune baseline (paper's
FI/FR comparison arm).

Duplex step dataflow (paper Fig 9):
  1. backbone forward in bf16 under stop_gradient, collecting per-superblock
     taps — XLA stores no backbone residuals;
  2. reversible branch over pooled streams (O(1) residuals, custom_vjp);
  3. correction added to backbone hidden; frozen unembedding produces logits;
  4. gradients/optimizer touch ONLY the branch params (tiny optimizer state).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.common import ModelConfig
from repro.core import duplex as dx
from repro.models import layers as L
from repro.optim import (AdamWConfig, OptConfig, SGDConfig, opt_init,
                         opt_update)
from repro.train.losses import lm_cross_entropy
from repro.utils import cast_tree


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    mode: str = "duplex"                   # duplex | full
    duplex: dx.DuplexConfig = dx.DuplexConfig()
    opt: OptConfig = SGDConfig()
    lr: float = 1e-3
    lr_schedule: Callable | None = None    # step → lr (overrides .lr)
    z_loss: float = 1e-4
    aux_weight: float = 1e-2               # MoE load-balance weight (full mode)
    microbatch: int = 1                    # gradient-accumulation splits
    backbone_dtype: jnp.dtype = jnp.bfloat16   # frozen storage precision


def tap_indices(n_rep: int, n_blocks: int) -> np.ndarray:
    """Evenly spaced backbone superblocks feeding the branch blocks."""
    if n_rep <= 0:
        raise ValueError("backbone has no scanned blocks to tap")
    return np.round(np.linspace(0, n_rep - 1, n_blocks)).astype(np.int32)


def init_state(key: jax.Array, entry, cfg: ModelConfig, tcfg: TrainConfig,
               policy: L.Policy = L.Policy()) -> dict:
    kb, kd = jax.random.split(key)
    backbone = entry.module.init_params(kb, cfg)
    if tcfg.mode == "duplex":
        backbone = cast_tree(backbone, tcfg.backbone_dtype)  # frozen → bf16
        branch = dx.duplex_init(kd, tcfg.duplex, cfg.d_model)
        opt = opt_init(tcfg.opt, branch)
        return {"step": jnp.zeros((), jnp.int32), "backbone": backbone,
                "branch": branch, "opt": opt}
    opt = opt_init(tcfg.opt, backbone)
    return {"step": jnp.zeros((), jnp.int32), "backbone": backbone,
            "opt": opt}


def _lr(tcfg: TrainConfig, step):
    if tcfg.lr_schedule is not None:
        return tcfg.lr_schedule(step)
    return jnp.full((), tcfg.lr, jnp.float32)


def _microbatches(batch: dict, k: int) -> dict:
    return jax.tree_util.tree_map(
        lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)


def make_train_step(entry, cfg: ModelConfig, tcfg: TrainConfig,
                    policy: L.Policy = L.Policy()):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    batch: {"tokens" [B,S] int32, "labels" [B,S] int32, optional "mask",
    optional "frontend" dict of stub embeddings}.
    """
    module = entry.module

    if tcfg.mode == "duplex":
        n_rep = cfg.n_rep
        idx = tap_indices(n_rep, tcfg.duplex.n_blocks)

        def loss_fn(branch, backbone, batch):
            fe = batch.get("frontend")
            kw = {} if fe is None else {"frontend": fe}
            out = module.forward(backbone, cfg, batch["tokens"],
                                 collect_taps=True, tap_indices=idx,
                                 tap_pool=tcfg.duplex.pool_factor,
                                 policy=policy, **kw)
            taps = out["taps"]               # [n_blocks,B,S/pool,D] pooled
            corr = dx.duplex_apply(branch, tcfg.duplex, out["emb"], taps,
                                   policy=policy, taps_pooled=True)
            hidden = jax.lax.stop_gradient(out["hidden"]) + corr
            logits = module.lm_logits(backbone, cfg, hidden, policy)
            loss, metrics = lm_cross_entropy(logits, batch["labels"],
                                             batch.get("mask"),
                                             z_loss=tcfg.z_loss)
            return loss, metrics

        trainable = "branch"
    else:
        def loss_fn(backbone, _unused, batch):
            fe = batch.get("frontend")
            kw = {} if fe is None else {"frontend": fe}
            out = module.forward(backbone, cfg, batch["tokens"],
                                 policy=policy, **kw)
            logits = module.lm_logits(backbone, cfg, out["hidden"], policy)
            loss, metrics = lm_cross_entropy(logits, batch["labels"],
                                             batch.get("mask"),
                                             z_loss=tcfg.z_loss)
            loss = loss + tcfg.aux_weight * out["aux"]
            return loss, metrics

        trainable = "backbone"

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        frozen = state["backbone"] if tcfg.mode == "duplex" else None

        if tcfg.microbatch > 1:
            mb = _microbatches(batch, tcfg.microbatch)

            def acc_body(carry, mbatch):
                gacc, lacc = carry
                (loss, metrics), g = grad_fn(state[trainable], frozen, mbatch)
                gacc = jax.tree_util.tree_map(jnp.add, gacc, g)
                return (gacc, lacc + loss), metrics

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state[trainable])
            (gsum, lsum), ms = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree_util.tree_map(
                lambda g: g / tcfg.microbatch, gsum)
            metrics = jax.tree_util.tree_map(jnp.mean, ms)
        else:
            (loss, metrics), grads = grad_fn(state[trainable], frozen, batch)

        lr = _lr(tcfg, state["step"])
        new_p, new_opt, om = opt_update(tcfg.opt, grads, state["opt"],
                                        state[trainable], lr)
        new_state = dict(state)
        new_state[trainable] = new_p
        new_state["opt"] = new_opt
        new_state["step"] = state["step"] + 1
        return new_state, {**metrics, **om, "lr": lr}

    return train_step
