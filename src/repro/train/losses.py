"""Losses: next-token cross-entropy with padded-vocab masking + z-loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_cross_entropy(logits: jax.Array, labels: jax.Array,
                     mask: jax.Array | None = None,
                     z_loss: float = 0.0):
    """logits [B,S,Vp] (padded rows already −inf-masked), labels [B,S].

    Returns (loss, metrics).  ``mask`` [B,S] ∈ {0,1} excludes padding tokens.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        loss = jnp.mean(nll)
        denom = nll.size
    else:
        mask = mask.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(nll * mask) / denom
    acc = (jnp.argmax(logits, -1) == labels)
    if mask is not None:
        acc = jnp.sum(acc * mask) / denom
    else:
        acc = jnp.mean(acc)
    return loss, {"loss": loss, "accuracy": acc}


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array):
    """Classification loss (paper's Table II benchmarks). labels [B] int."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return jnp.mean(nll), {"loss": jnp.mean(nll), "accuracy": acc}
