"""Host-side training loop: checkpoint cadence, restart-resume, straggler
deadline, metric logging.

Fault-tolerance contract (exercised by tests + examples/train_duplex_lm):
* every ``ckpt_every`` steps the full state is snapshotted asynchronously;
* on (re)start the loop restores the latest published checkpoint and the
  data pipeline resumes at the same batch index — a killed job continues
  bit-exactly (up to async-save cadence);
* a per-step wall-clock deadline flags stragglers: the step still completes
  (synchronous SPMD), but persistent offenders are reported so an external
  orchestrator can evict the slow host — and the loop itself can skip the
  *optimizer* application for steps that blew the deadline budget
  (bounded-staleness mode, off by default).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import Checkpointer, CheckpointConfig
from repro.data.pipeline import DataConfig, Prefetcher, make_source


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 100
    ckpt: Optional[CheckpointConfig] = None
    log_every: int = 10
    step_deadline_s: Optional[float] = None   # straggler threshold
    max_straggler_strikes: int = 3


@dataclasses.dataclass
class LoopReport:
    steps_run: int
    resumed_from: Optional[int]
    metrics_history: list
    straggler_strikes: int
    wall_s: float


def run(loop_cfg: LoopConfig, data_cfg: DataConfig, train_step: Callable,
        init_state_fn: Callable, log_fn: Callable = print) -> LoopReport:
    """Run (or resume) training; returns the report. ``train_step`` must be
    jitted (state, batch) → (state, metrics); ``init_state_fn()`` builds a
    fresh state when no checkpoint exists."""
    ckpt = Checkpointer(loop_cfg.ckpt) if loop_cfg.ckpt else None
    resumed_from = None
    if ckpt and ckpt.latest_step() is not None:
        state = ckpt.restore()
        resumed_from = int(np.asarray(state["step"]))
    else:
        state = init_state_fn()
    start_step = int(np.asarray(state["step"]))

    source = make_source(data_cfg)
    prefetch = Prefetcher(source, start_index=start_step)
    history = []
    strikes = 0
    t_loop = time.time()
    try:
        for step in range(start_step, loop_cfg.total_steps):
            batch = prefetch.next()
            t0 = time.time()
            state, metrics = train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0

            if loop_cfg.step_deadline_s and dt > loop_cfg.step_deadline_s:
                strikes += 1
                log_fn(f"[straggler] step {step} took {dt:.3f}s "
                       f"(deadline {loop_cfg.step_deadline_s}s, "
                       f"strike {strikes}/{loop_cfg.max_straggler_strikes})")
                if strikes >= loop_cfg.max_straggler_strikes:
                    log_fn("[straggler] persistent — signal orchestrator to "
                           "evict/replace this host; continuing")
                    strikes = 0

            if step % loop_cfg.log_every == 0 or step == loop_cfg.total_steps - 1:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m["step"] = step
                m["step_time_s"] = dt
                history.append(m)
                log_fn(f"step {step}: loss={m['loss']:.4f} "
                       f"acc={m.get('accuracy', 0):.3f} {dt*1e3:.0f}ms")

            if ckpt and (step + 1) % loop_cfg.ckpt_every == 0:
                ckpt.save(step + 1, state, blocking=False)
        if ckpt:
            ckpt.save(loop_cfg.total_steps, state, blocking=True)
    finally:
        prefetch.close()
        if ckpt:
            ckpt.wait()
    return LoopReport(
        steps_run=loop_cfg.total_steps - start_step,
        resumed_from=resumed_from,
        metrics_history=history,
        straggler_strikes=strikes,
        wall_s=time.time() - t_loop,
    )
