"""Fault-tolerant checkpointing (no orbax in this environment).

Design for 1000+ nodes (DESIGN.md §6):
* **sharded**: each host serializes only the array shards it owns
  (addressable shards), so checkpoint bandwidth scales with hosts;
* **atomic**: writes go to ``step_N.tmp/`` then a single rename publishes;
  a crashed writer never corrupts the latest checkpoint;
* **self-describing**: a msgpack manifest carries the pytree structure,
  global shapes/dtypes, and the mesh/sharding layout it was saved under;
* **elastic restore**: arrays are reassembled to their global shape and
  re-sharded onto the *restore* mesh, which may differ from the save mesh
  (scale up/down after node failure);
* **integrity**: per-file crc32 recorded in the manifest and verified;
* **async**: ``save(..., blocking=False)`` snapshots to host memory and
  writes on a background thread — the train loop keeps stepping;
* **keep-k**: old steps are garbage-collected after a successful publish.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:              # optional: falls back to uncompressed blobs
    zstandard = None

from repro.utils import path_str

_MANIFEST = "manifest.msgpack"


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    directory: str
    keep: int = 3
    compress_level: int = 3      # zstd; 0 disables


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_str(p), x) for p, x in flat]


def _nested_skeleton(tree: Any):
    if isinstance(tree, dict):
        return {k: _nested_skeleton(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_nested_skeleton(v) for v in tree]
    return None


def _rebuild(skel, values: dict, prefix=""):
    if isinstance(skel, dict):
        return {k: _rebuild(v, values, f"{prefix}{k}/")
                for k, v in skel.items()}
    if isinstance(skel, list):
        return [_rebuild(v, values, f"{prefix}{i}/")
                for i, v in enumerate(skel)]
    return values[prefix[:-1]]


class Checkpointer:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self.dir = Path(cfg.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        """Snapshot ``state`` (device → host) and persist it."""
        self.wait()                      # one in-flight save at a time
        host = jax.tree_util.tree_map(np.asarray, state)   # sync snapshot
        if blocking:
            self._write(step, host)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state: Any) -> None:
        final = self.dir / f"step_{step:012d}"
        tmp = self.dir / f"step_{step:012d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        cctx = zstandard.ZstdCompressor(level=self.cfg.compress_level) \
            if (self.cfg.compress_level and zstandard is not None) else None

        entries = {}
        for i, (path, leaf) in enumerate(_leaf_paths(host_state)):
            arr = np.asarray(leaf)
            fname = f"arr_{i:06d}.bin"
            raw = arr.tobytes()
            blob = cctx.compress(raw) if cctx else raw
            (tmp / fname).write_bytes(blob)
            entries[path] = {
                "file": fname,
                "dtype": arr.dtype.str,
                "shape": list(arr.shape),
                "crc32": zlib.crc32(blob) & 0xFFFFFFFF,
                "compressed": bool(cctx),
            }
        manifest = {
            "step": step,
            "skeleton": _nested_skeleton(host_state),
            "entries": entries,
            "format": 1,
        }
        (tmp / _MANIFEST).write_bytes(msgpack.packb(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.cfg.keep]:
            shutil.rmtree(self.dir / f"step_{s:012d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / _MANIFEST).exists():
                continue                 # unpublished/corrupt: ignored
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Load a checkpoint; optionally re-shard onto a (new) mesh.

        ``shardings``: pytree of NamedShardings matching the state — enables
        elastic restore onto a different mesh than the one saved under.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:012d}"
        manifest = msgpack.unpackb((d / _MANIFEST).read_bytes())
        dctx = zstandard.ZstdDecompressor() if zstandard is not None else None

        values = {}
        for path, e in manifest["entries"].items():
            blob = (d / e["file"]).read_bytes()
            if (zlib.crc32(blob) & 0xFFFFFFFF) != e["crc32"]:
                raise IOError(f"checksum mismatch for {path} at step {step}")
            if e["compressed"]:
                if dctx is None:
                    raise ImportError(
                        f"checkpoint step {step} is zstd-compressed but "
                        "the 'zstandard' package is not installed")
                raw = dctx.decompress(blob)
            else:
                raw = blob
            arr = np.frombuffer(raw, dtype=np.dtype(e["dtype"])).reshape(
                e["shape"]).copy()       # writable
            values[path] = arr
        state = _rebuild(manifest["skeleton"], values)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(jnp.asarray(x), s),
                state, shardings)
        return state
