"""repro.ckpt"""
