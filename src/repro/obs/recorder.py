"""The flight recorder: typed spans + counter series for one sim run.

A :class:`SpanRecorder` is an opt-in sink threaded through the pipeline
(``sim.run(arm, trace=...)`` → ``SimContext.recorder`` → the timeline
engine and the controller replay).  With no recorder attached every
instrumentation site is a no-op and the simulation is bit-identical —
the recorder only *observes*; it never feeds anything back into timing
or energy.

Span kinds (:data:`SPAN_KINDS`):

``op``
    One schedule op on the pushed-back (closed-loop) timeline.  Args
    carry the unconstrained schedule position (``sched_start_s`` /
    ``sched_end_s``) and the pushback this op's ports added
    (``pushback_s``), so conflict stall is visible per op.
``port``
    One op's port service on one bank — ``[start, start + slowest
    port)`` with the read/write word counts in args.
``refresh``
    A *hidden* refresh pulse, placed inside a bank-idle window (energy
    charged, zero stall).  Args: retention ``tick``, starting ``row``,
    ``rows`` multiplicity, ``words`` moved, ``deadline_s``.
``refresh_stall``
    A pulse (or an aggregated preempting run of row pulses) that found
    no idle window: it preempts at its deadline and stalls the ports
    for ``stall_s`` seconds.
``spill``
    An off-chip transfer for a spilled tensor (zero-width: the replay
    charges energy, off-chip *time* is priced globally against
    ``SystemConfig.offchip_bw_bps``).

Counter series (:meth:`SpanRecorder.counter`) sample per-bank occupancy
in words at every allocate/free, cumulative traffic energy at each
charging event, per-bank refresh energy, and the energy stage's final
compute/leakage totals.  ``meta`` carries the run's scalars the
reconciliation needs (``schedule_s``, ``timing``, ``granularity``, …).

The recorded stream is a *checkable ground truth*: ``repro.obs.reconcile``
re-derives ``stall_s`` / ``refresh_stall_s`` / ``refresh_hidden_j`` /
``rows_refreshed`` from it and asserts exact equality with the
``ArmReport``, and ``repro.obs.export`` renders it as Chrome Trace Event
JSON for Perfetto.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

SPAN_KINDS = ("op", "port", "refresh", "refresh_stall", "spill")


@dataclasses.dataclass(frozen=True)
class Span:
    """One typed interval on the run's timeline (seconds, t0 <= t1)."""
    kind: str
    name: str
    t0: float
    t1: float
    bank: int = -1                  # -1: not bank-scoped (op/spill spans)
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def dur_s(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass(frozen=True)
class CounterSample:
    """One sample of a (possibly per-bank) counter series."""
    name: str
    t: float
    value: float
    bank: int = -1


class SpanRecorder:
    """Append-only sink for spans, counter samples, and run metadata."""

    def __init__(self) -> None:
        self.spans: list[Span] = []
        self.counters: list[CounterSample] = []
        self.meta: dict = {}

    def __len__(self) -> int:
        return len(self.spans)

    def span(self, kind: str, name: str, t0: float, t1: float,
             bank: int = -1, **args) -> None:
        """Record one span; ``args`` is the kind-specific payload."""
        if kind not in SPAN_KINDS:
            raise ValueError(f"unknown span kind {kind!r}; "
                             f"choose from {SPAN_KINDS}")
        self.spans.append(Span(kind=kind, name=name, t0=t0, t1=t1,
                               bank=bank, args=args))

    def counter(self, name: str, t: float, value: float,
                bank: int = -1) -> None:
        self.counters.append(CounterSample(name=name, t=t, value=value,
                                           bank=bank))

    # ---------------------------------------------------------- queries
    def spans_of(self, *kinds: str) -> Iterator[Span]:
        """Spans of the given kinds, in recorded order."""
        return (s for s in self.spans if s.kind in kinds)

    def banks(self) -> list[int]:
        """Sorted bank indices any span or counter touched."""
        seen = {s.bank for s in self.spans if s.bank >= 0}
        seen |= {c.bank for c in self.counters if c.bank >= 0}
        return sorted(seen)

    def bank_spans(self, bank: int, *kinds: str) -> list[Span]:
        """One bank's spans of the given kinds, in recorded order."""
        return [s for s in self.spans
                if s.bank == bank and (not kinds or s.kind in kinds)]

    def counter_samples(self, name: str, bank: int = -1) -> list:
        return [c for c in self.counters
                if c.name == name and c.bank == bank]

    def makespan_s(self) -> float:
        """Last op/port span end — the walked timeline's makespan (0.0
        when no op ran)."""
        return max((s.t1 for s in self.spans_of("op", "port")),
                   default=0.0)
