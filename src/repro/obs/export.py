"""Chrome Trace Event JSON export (opens directly in Perfetto).

Renders a :class:`~repro.obs.recorder.SpanRecorder` as the Trace Event
Format's JSON *object* form::

    {"traceEvents": [...], "displayTimeUnit": "ms",
     "otherData": {"meta": {...}, "report": {...}}}

Layout: one pid per timeline track owner — pid 0 is the compute array
(op spans, off-chip spill instants, cumulative energy counters), pid
``1 + bank`` is one eDRAM/SRAM bank with three tids (port service,
hidden refresh pulses, preempting refresh stalls) plus its occupancy and
refresh-energy counters.  Duration spans are ``"X"`` events, counters
``"C"``, spills ``"i"`` instants, and track names ``"M"`` metadata.

``ts``/``dur`` are microseconds (the format's unit); every event also
carries the *raw second-domain* values in ``args`` (``t0_s``/``t1_s``,
counter ``t_s``/``value``), which are the authoritative numbers —
:func:`recorder_from_trace` rebuilds a recorder from them losslessly
(floats survive JSON round-trips exactly), so an exported trace can be
reconciled against its embedded report by ``tools/check_trace.py``.

Events are sorted by ``ts`` (metadata first); span tracks (op / port /
hidden-refresh) are non-overlapping by construction of the timeline
engine — both properties are what ``tools/check_trace.py`` validates.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.obs.recorder import CounterSample, Span, SpanRecorder

# pid of the compute array track; banks are PID_BANK0 + bank index
PID_ARRAY = 0
PID_BANK0 = 1

# tids inside a bank's process
TID_PORT = 0
TID_REFRESH = 1
TID_REFRESH_STALL = 2

_SPAN_TID = {"op": 0, "spill": 1,
             "port": TID_PORT, "refresh": TID_REFRESH,
             "refresh_stall": TID_REFRESH_STALL}
_TRACK_NAMES = {
    (PID_ARRAY, 0): "ops",
    (PID_ARRAY, 1): "off-chip spills",
    TID_PORT: "port",
    TID_REFRESH: "refresh (hidden)",
    TID_REFRESH_STALL: "refresh (stall)",
}


def _us(t_s: float) -> float:
    return t_s * 1e6


def _pid(span_or_counter) -> int:
    bank = span_or_counter.bank
    return PID_ARRAY if bank < 0 else PID_BANK0 + bank


def chrome_trace_events(recorder: SpanRecorder) -> list[dict]:
    """The recorder's spans/counters as a sorted Trace Event list."""
    events: list[dict] = []
    pids = {PID_ARRAY: "array"}
    for b in recorder.banks():
        pids[PID_BANK0 + b] = f"bank {b}"

    for s in recorder.spans:
        pid = _pid(s)
        tid = _SPAN_TID[s.kind]
        args = {**s.args, "t0_s": s.t0, "t1_s": s.t1}
        if s.bank >= 0:
            args["bank"] = s.bank
        if s.kind == "spill":                  # zero-width: instant event
            events.append({"ph": "i", "s": "t", "pid": pid, "tid": tid,
                           "ts": _us(s.t0), "name": s.name,
                           "cat": s.kind, "args": args})
            continue
        events.append({"ph": "X", "pid": pid, "tid": tid,
                       "ts": _us(s.t0), "dur": _us(s.t1) - _us(s.t0),
                       "name": s.name, "cat": s.kind, "args": args})

    for c in recorder.counters:
        pid = _pid(c)
        args = {"value": c.value, "t_s": c.t}
        if c.bank >= 0:
            args["bank"] = c.bank
        events.append({"ph": "C", "pid": pid, "ts": _us(c.t),
                       "name": c.name, "cat": "counter", "args": args})

    events.sort(key=lambda e: e["ts"])

    meta: list[dict] = []
    for pid, name in sorted(pids.items()):
        meta.append({"ph": "M", "pid": pid, "ts": 0, "name": "process_name",
                     "args": {"name": name}})
    tids = sorted({(e["pid"], e["tid"]) for e in events if "tid" in e})
    for pid, tid in tids:
        label = _TRACK_NAMES.get((pid, tid)) or _TRACK_NAMES.get(tid) \
            or f"track {tid}"
        meta.append({"ph": "M", "pid": pid, "tid": tid, "ts": 0,
                     "name": "thread_name", "args": {"name": label}})
    return meta + events


def trace_dict(recorder: SpanRecorder, report=None) -> dict:
    """The full JSON-object-form trace.  ``report`` (an ``ArmReport`` or
    its ``to_dict()`` form) is embedded under ``otherData.report`` so the
    trace file is self-contained for reconciliation."""
    other: dict = {"meta": dict(recorder.meta)}
    if report is not None:
        other["report"] = (report.to_dict()
                           if hasattr(report, "to_dict") else dict(report))
    return {"traceEvents": chrome_trace_events(recorder),
            "displayTimeUnit": "ms", "otherData": other}


def export_chrome_trace(recorder: SpanRecorder, path, report=None) -> str:
    """Write the trace to ``path``; returns the path written.  Open the
    file in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``."""
    with open(path, "w") as f:
        json.dump(trace_dict(recorder, report=report), f)
    return str(path)


def recorder_from_trace(trace: dict) -> tuple[SpanRecorder, Optional[dict]]:
    """Rebuild ``(recorder, embedded report dict or None)`` from a trace
    produced by :func:`trace_dict` / :func:`export_chrome_trace`.

    Uses the raw second-domain values each event carries in ``args``
    (not the µs ``ts``), so the rebuilt recorder reconciles *exactly*
    against the embedded report.
    """
    rec = SpanRecorder()
    for e in trace.get("traceEvents", ()):
        ph, cat = e.get("ph"), e.get("cat")
        args = dict(e.get("args", {}))
        bank = args.pop("bank", -1)
        if ph in ("X", "i") and cat in _SPAN_TID:
            t0 = args.pop("t0_s")
            t1 = args.pop("t1_s")
            rec.spans.append(Span(kind=cat, name=e["name"], t0=t0, t1=t1,
                                  bank=bank, args=args))
        elif ph == "C":
            rec.counters.append(CounterSample(
                name=e["name"], t=args["t_s"], value=args["value"],
                bank=bank))
    other = trace.get("otherData", {})
    rec.meta = dict(other.get("meta", {}))
    return rec, other.get("report")
