"""``repro.obs`` — the opt-in flight-recorder/observability layer.

Four small pieces, none of which touch simulation results:

- :mod:`repro.obs.recorder` — :class:`SpanRecorder`: typed spans (op
  execution, port service, hidden vs stalling refresh pulses, off-chip
  spills) plus counter series (per-bank occupancy, cumulative energy),
  recorded by the timeline engine when ``sim.run(arm, trace=...)``
  passes one in.
- :mod:`repro.obs.export` — Chrome Trace Event JSON (one pid per
  controller/bank) that opens directly in Perfetto.
- :mod:`repro.obs.reconcile` — re-derives ``stall_s`` /
  ``refresh_stall_s`` / ``refresh_hidden_j`` / ``rows_refreshed`` from
  the spans and asserts exact equality with the ``ArmReport``, so the
  trace is a checkable ground truth rather than a parallel bookkeeping
  path.
- :mod:`repro.obs.log` — structured stderr diagnostics (level via the
  ``REPRO_LOG`` env var) keeping benchmark stdout machine-separable.

Quick capture::

    from repro import obs, sim

    rep = sim.run(sim.get_arm("DuDNN+CAMEL"), trace=True)
    obs.export_chrome_trace(rep.trace, "camel.trace.json", report=rep)
    assert obs.reconcile(rep.trace, rep).ok

See ``docs/observability.md`` for the span/counter semantics and the
stage profiler (``sim.run(profile=True)``).
"""
from repro.obs import log
from repro.obs.export import (chrome_trace_events, export_chrome_trace,
                              recorder_from_trace, trace_dict)
from repro.obs.recorder import (SPAN_KINDS, CounterSample, Span,
                                SpanRecorder)
from repro.obs.reconcile import (RECONCILED_FIELDS, FieldCheck,
                                 ReconcileResult, derive, reconcile)

__all__ = [
    "SPAN_KINDS", "RECONCILED_FIELDS", "CounterSample", "FieldCheck",
    "ReconcileResult", "Span", "SpanRecorder", "aggregate_profiles",
    "chrome_trace_events", "derive", "export_chrome_trace", "log",
    "reconcile", "recorder_from_trace", "trace_dict",
]


def aggregate_profiles(reports) -> dict:
    """Aggregate ``sim.sweep(..., profile=True)`` stage timings across a
    grid: ``{stage: {"total_s", "mean_s", "max_s"}}`` over the reports
    that carry a profile (``report.profile["stages"]``)."""
    stages: dict[str, list[float]] = {}
    for rep in reports:
        prof = rep.profile if hasattr(rep, "profile") else rep.get("profile")
        if not prof:
            continue
        for name, wall in prof["stages"].items():
            stages.setdefault(name, []).append(wall)
    return {name: {"total_s": sum(walls), "mean_s": sum(walls) / len(walls),
                   "max_s": max(walls)}
            for name, walls in stages.items()}
