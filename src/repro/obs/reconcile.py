"""Span-vs-report reconciliation — the trace as checkable ground truth.

The flight recorder and the :class:`~repro.sim.report.ArmReport` are fed
by the same engine but through *different aggregation paths*: the report
sums scalars as the timeline model runs, the recorder keeps every span.
:func:`reconcile` re-derives the report's stall/refresh scalars from the
recorded spans and asserts **exact** (``==``) equality, replicating the
engine's summation grouping (per-bank partial sums in bank order — float
addition is not associative, so the grouping is part of the contract):

- ``refresh_stall_s`` — per bank, the sum of its pulse spans'
  ``stall_s`` in recorded order; banks summed in ascending index order
  (mirrors ``RefreshScheduler.account`` + ``build_report``).
- ``stall_s`` — the above plus conflict stall, where conflict stall is
  ``max(makespan, schedule_s) - schedule_s`` and the makespan is the
  last op/port span end (mirrors ``replay_timeline``).
- ``refresh_hidden_j`` — per bank, ``refresh_j × hidden / count`` with
  the hidden/total pulse multiplicities counted from spans and the
  bank's refresh energy read from its ``refresh_j`` counter sample
  (energy lives in the trace as a counter series; the hiding *split* is
  re-derived from spans).
- ``rows_refreshed`` — the summed ``rows`` multiplicity of all pulse
  spans under row granularity (0 under bank granularity).

A mismatch means the trace and the report have diverged — i.e. the
recorder is lying about what the engine did — which is exactly the
regression this module exists to catch.  Works on a live recorder or on
one rebuilt from an exported trace file
(:func:`repro.obs.export.recorder_from_trace`); floats survive the JSON
round-trip exactly.
"""
from __future__ import annotations

import dataclasses

from repro.obs.recorder import SpanRecorder

#: report fields reconcile() checks, in reporting order
RECONCILED_FIELDS = ("stall_s", "refresh_stall_s", "refresh_hidden_j",
                     "rows_refreshed")


@dataclasses.dataclass(frozen=True)
class FieldCheck:
    """One reconciled field: the report's value vs the span-derived one."""
    field: str
    reported: float
    derived: float

    @property
    def ok(self) -> bool:
        return self.reported == self.derived


@dataclasses.dataclass(frozen=True)
class ReconcileResult:
    checks: tuple

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.checks)

    def failures(self) -> list:
        return [c for c in self.checks if not c.ok]

    def __str__(self) -> str:
        return "\n".join(
            f"{'ok ' if c.ok else 'MISMATCH'} {c.field}: "
            f"report={c.reported!r} derived={c.derived!r}"
            for c in self.checks)


def _field(report, name):
    """Read a report field from an ``ArmReport`` or its dict form."""
    if hasattr(report, name):
        return getattr(report, name)
    return report[name]


def derive(recorder: SpanRecorder) -> dict:
    """Re-derive the reconciled scalars from the recorded spans/counters.

    Returns ``{"stall_s", "conflict_stall_s", "refresh_stall_s",
    "refresh_hidden_j", "rows_refreshed", "makespan_s"}``.  Requires a
    timeline-model trace (``meta["timing"] == "timeline"``).
    """
    timing = recorder.meta.get("timing")
    if timing != "timeline":
        raise ValueError(
            f"reconciliation needs a timeline-model trace, got "
            f"timing={timing!r} (additive/scalar runs aggregate stalls "
            f"without placing spans)")
    schedule_s = recorder.meta["schedule_s"]

    makespan = recorder.makespan_s()
    makespan = max(makespan, schedule_s)
    conflict_stall_s = makespan - schedule_s

    # per-bank partial sums in ascending bank order — the same grouping
    # account()/build_report() use, so float totals match bit-for-bit
    refresh_stall_s = 0.0
    refresh_hidden_j = 0.0
    rows = 0
    row_granular = recorder.meta.get("granularity") == "row"
    for bank in recorder.banks():
        pulses = recorder.bank_spans(bank, "refresh", "refresh_stall")
        if not pulses:
            continue
        refresh_stall_s += sum(p.args["stall_s"] for p in pulses)
        hidden = sum(p.args["rows"] for p in pulses if p.kind == "refresh")
        count = sum(p.args["rows"] for p in pulses)
        if row_granular:
            rows += count
        samples = recorder.counter_samples("refresh_j", bank=bank)
        refresh_j = samples[-1].value if samples else 0.0
        if count:
            refresh_hidden_j += refresh_j * hidden / count

    return {
        "makespan_s": makespan,
        "conflict_stall_s": conflict_stall_s,
        "refresh_stall_s": refresh_stall_s,
        "stall_s": conflict_stall_s + refresh_stall_s,
        "refresh_hidden_j": refresh_hidden_j,
        "rows_refreshed": rows,
    }


def reconcile(recorder: SpanRecorder, report) -> ReconcileResult:
    """Check the recorded spans against ``report`` (an ``ArmReport`` or
    its ``to_dict()`` form); every :data:`RECONCILED_FIELDS` entry must
    match **exactly**.

    Raises ``ValueError`` on a non-timeline trace; returns a
    :class:`ReconcileResult` whose ``.ok`` is the verdict.
    """
    derived = derive(recorder)
    checks = [FieldCheck(field=name, reported=_field(report, name),
                         derived=derived[name])
              for name in RECONCILED_FIELDS]
    return ReconcileResult(checks=tuple(checks))
