"""Tiny structured logger for benchmark/sim diagnostics.

Benchmark suites print machine-readable CSV rows on **stdout** (and
``benchmarks.run --json`` collects them as records); anything that is a
*diagnostic* — warnings about operating points, sweep progress — goes
through this module to **stderr**, so the two streams stay separable.

One line per event, ``key=value`` fields after the event name::

    [repro:warn] pulse_exceeds_retention arm=DuDNN+CAMEL/T100 freq_mhz=250

The threshold comes from the ``REPRO_LOG`` environment variable
(``debug`` | ``info`` | ``warn`` | ``error``; default ``warn``) and is
read per call, so tests and long-running processes can flip it without
re-importing.  ``force=True`` bypasses the threshold — used when the
caller explicitly asked for the output (e.g. ``sim.sweep(progress=True)``).
"""
from __future__ import annotations

import os
import sys

LEVELS = {"debug": 10, "info": 20, "warn": 30, "error": 40}
DEFAULT_LEVEL = "warn"
ENV_VAR = "REPRO_LOG"


def threshold() -> int:
    """The active numeric threshold (unknown env values fall back to the
    default so a typo never silences errors *and* never spams debug)."""
    name = os.environ.get(ENV_VAR, DEFAULT_LEVEL).strip().lower()
    return LEVELS.get(name, LEVELS[DEFAULT_LEVEL])


def enabled(level: str) -> bool:
    return LEVELS.get(level, LEVELS["error"]) >= threshold()


def _fmt(value) -> str:
    if isinstance(value, float):
        return f"{value:g}"
    s = str(value)
    return f'"{s}"' if " " in s else s


def log(level: str, event: str, *, force: bool = False,
        file=None, **fields) -> bool:
    """Emit one structured line to stderr; returns whether it printed.

    Args:
        level: ``debug`` | ``info`` | ``warn`` | ``error``.
        event: short snake_case event name (the grep handle).
        force: print regardless of the ``REPRO_LOG`` threshold.
        file: output stream override (default ``sys.stderr``).
        fields: key=value payload, formatted ``%g`` for floats.
    """
    if not (force or enabled(level)):
        return False
    parts = [f"[repro:{level}] {event}"]
    parts += [f"{k}={_fmt(v)}" for k, v in fields.items()]
    print(" ".join(parts), file=file if file is not None else sys.stderr)
    return True


def debug(event: str, **fields) -> bool:
    return log("debug", event, **fields)


def info(event: str, **fields) -> bool:
    return log("info", event, **fields)


def warn(event: str, **fields) -> bool:
    return log("warn", event, **fields)


def error(event: str, **fields) -> bool:
    return log("error", event, **fields)
