"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 LRU
(arXiv:2402.19427).  38L d4096 16H (MQA kv=1) d_ff 12288 vocab 256000,
window 2048.  38 = 12×(lru,lru,local) + (lru,lru) remainder.
Sub-quadratic (windowed attention) ⇒ runs the long_500k cell."""
from repro.configs.common import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", vocab=256_000,
    d_model=4096, n_layers=38,
    pattern=(LayerSpec("lru", "dense"), LayerSpec("lru", "dense"),
             LayerSpec("local", "dense")),
    remainder=(LayerSpec("lru", "dense"), LayerSpec("lru", "dense")),
    n_heads=16, n_kv=1, head_dim=256, d_ff=12_288,
    lru_width=4096, window=2048,
    embed_scale=True, act="gelu",
    supports_long_context=True,
).validate()

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid", vocab=128,
    d_model=32, n_layers=5,
    pattern=(LayerSpec("lru", "dense"), LayerSpec("lru", "dense"),
             LayerSpec("local", "dense")),
    remainder=(LayerSpec("lru", "dense"), LayerSpec("lru", "dense")),
    n_heads=4, n_kv=1, head_dim=8, d_ff=64,
    lru_width=32, window=8,
    embed_scale=True, act="gelu",
    supports_long_context=True, vocab_pad_multiple=16,
).validate()
