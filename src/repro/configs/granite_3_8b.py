"""granite-3-8b [dense] — GQA (hf:ibm-granite/granite-3.0-2b-base).
40L d4096 32H (GQA kv=8) d_ff 12800 vocab 49155."""
from repro.configs.common import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="granite-3-8b", family="dense", vocab=49_155,
    d_model=4096, n_layers=40, pattern=(LayerSpec("attn", "dense"),),
    n_heads=32, n_kv=8, head_dim=128, d_ff=12_800,
    rope_theta=10_000.0,
).validate()

SMOKE = ModelConfig(
    name="granite3-smoke", family="dense", vocab=130,  # odd vocab: pad path
    d_model=32, n_layers=2, pattern=(LayerSpec("attn", "dense"),),
    n_heads=4, n_kv=2, head_dim=8, d_ff=64,
    rope_theta=10_000.0, vocab_pad_multiple=16,
).validate()
