"""gemma2-9b [dense] — local+global alternating, logit softcap
(arXiv:2408.00118; hf).  42L d3584 16H (GQA kv=8) d_ff 14336 vocab 256000."""
from repro.configs.common import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="gemma2-9b", family="dense", vocab=256_000,
    d_model=3584, n_layers=42,
    pattern=(LayerSpec("local", "dense"), LayerSpec("attn", "dense")),
    n_heads=16, n_kv=8, head_dim=256, d_ff=14_336,
    window=4096, softcap_attn=50.0, softcap_final=30.0,
    post_norm=True, embed_scale=True, act="gelu",
    rope_theta=10_000.0,
).validate()

SMOKE = ModelConfig(
    name="gemma2-smoke", family="dense", vocab=128,
    d_model=32, n_layers=4,
    pattern=(LayerSpec("local", "dense"), LayerSpec("attn", "dense")),
    n_heads=4, n_kv=2, head_dim=8, d_ff=64,
    window=8, softcap_attn=50.0, softcap_final=30.0,
    post_norm=True, embed_scale=True, act="gelu",
    vocab_pad_multiple=16,
).validate()
