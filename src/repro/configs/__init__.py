"""repro.configs"""
