"""starcoder2-7b [dense] — GQA, RoPE (arXiv:2402.19173; hf).
32L d4608 36H (GQA kv=4) d_ff 18432 vocab 49152.  36 heads do not divide the
TP axis (16) ⇒ attention runs in sequence-parallel mode (DESIGN.md §6)."""
from repro.configs.common import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="starcoder2-7b", family="dense", vocab=49_152,
    d_model=4608, n_layers=32, pattern=(LayerSpec("attn", "dense"),),
    n_heads=36, n_kv=4, head_dim=128, d_ff=18_432,
    norm="layernorm", act="gelu", gated_mlp=False,
    rope_theta=100_000.0, qkv_bias=True,
).validate()

SMOKE = ModelConfig(
    name="starcoder2-smoke", family="dense", vocab=128,
    d_model=36, n_layers=3, pattern=(LayerSpec("attn", "dense"),),
    n_heads=6, n_kv=2, head_dim=8, d_ff=64,
    norm="layernorm", act="gelu", gated_mlp=False,
    rope_theta=100_000.0, qkv_bias=True, vocab_pad_multiple=16,
).validate()
