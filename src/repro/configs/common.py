"""Unified model-configuration schema for every assigned architecture.

A model is a token embedding + a *pattern* of layer specs repeated
``n_rep`` times (scanned, so the HLO stays compact at 80+ layers) + an
optional non-repeating ``remainder`` + final norm + tied unembedding.

Layer kinds: ``attn`` (global self), ``local`` (sliding window),
``cross`` (cross-attention to a frontend/encoder stream), ``ssd``
(mamba2 mixer), ``lru`` (RG-LRU recurrent block).  Each spec also names
its channel mixer: ``dense`` | ``moe`` | ``none``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str                 # attn | local | cross | ssd | lru
    mlp: str = "dense"        # dense | moe | none


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    vocab: int
    d_model: int
    n_layers: int
    pattern: Tuple[LayerSpec, ...]
    remainder: Tuple[LayerSpec, ...] = ()

    # attention
    n_heads: int = 0
    n_kv: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: Optional[float] = 10000.0
    pos_embed: str = "rope"   # rope | sinusoidal | none
    window: Optional[int] = None
    softcap_attn: Optional[float] = None
    softcap_final: Optional[float] = None
    causal: bool = True
    post_norm: bool = False   # gemma2-style post-sublayer norms
    q_chunk: int = 512
    kv_chunk: int = 1024
    blockwise_threshold: int = 1024
    causal_skip: bool = False  # §Perf knob: skip fully-masked kv chunks
    use_flash: bool = False    # fused Pallas flash attention (TPU runtime)

    # mlp
    d_ff: int = 0
    gated_mlp: bool = True
    act: str = "silu"         # silu | gelu

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024
    shared_expert: bool = False

    # ssm (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128

    # lru (recurrentgemma)
    lru_width: int = 0
    conv_width: int = 4
    lru_scan_chunk: Optional[int] = None  # §Perf H2: chunked LRU scan

    # frontends / enc-dec
    encoder: Optional["ModelConfig"] = None   # whisper audio encoder
    n_frontend_tokens: int = 0                # stub frame/patch embeddings
    frontend_dim: int = 0

    # norms / vocab
    norm: str = "rmsnorm"     # rmsnorm | layernorm
    vocab_pad_multiple: int = 256
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scale

    # attention families that are quadratic cannot serve 500k contexts
    supports_long_context: bool = False

    @property
    def n_rep(self) -> int:
        body = self.n_layers - len(self.remainder)
        if self.pattern and body % len(self.pattern):
            raise ValueError(
                f"{self.name}: {body} layers not divisible by pattern "
                f"{len(self.pattern)}")
        return body // len(self.pattern) if self.pattern else 0

    def validate(self) -> "ModelConfig":
        _ = self.n_rep
        kinds = {s.kind for s in self.pattern + self.remainder}
        if kinds & {"attn", "local", "cross"}:
            assert self.n_heads and self.n_kv and self.head_dim, self.name
            assert self.n_heads % self.n_kv == 0, self.name
        if any(s.mlp == "moe" for s in self.pattern + self.remainder):
            assert self.n_experts and self.top_k, self.name
        if "ssd" in kinds:
            assert self.ssm_state, self.name
        if "lru" in kinds:
            assert self.lru_width, self.name
        return self


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    mode: str                 # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
