"""qwen2-72b [dense] — GQA + QKV bias (arXiv:2407.10671; hf).
80L d8192 64H (GQA kv=8) d_ff 29568 vocab 152064."""
from repro.configs.common import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="qwen2-72b", family="dense", vocab=152_064,
    d_model=8192, n_layers=80, pattern=(LayerSpec("attn", "dense"),),
    n_heads=64, n_kv=8, head_dim=128, d_ff=29_568,
    qkv_bias=True, rope_theta=1_000_000.0,
).validate()

SMOKE = ModelConfig(
    name="qwen2-smoke", family="dense", vocab=128,
    d_model=32, n_layers=3, pattern=(LayerSpec("attn", "dense"),),
    n_heads=4, n_kv=2, head_dim=8, d_ff=64,
    qkv_bias=True, rope_theta=1_000_000.0, vocab_pad_multiple=16,
).validate()
