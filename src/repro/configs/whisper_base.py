"""whisper-base [audio] — enc-dec, conv frontend stubbed (arXiv:2212.04356).

6 encoder layers + 6 decoder layers (each decoder layer = self-attn +
cross-attn + MLP, expressed as a 2-spec pattern).  The audio conv frontend is
a stub: ``input_specs`` provides precomputed [B, 1500, 512] frame embeddings.
"""
from repro.configs.common import LayerSpec, ModelConfig

_ENC = ModelConfig(
    name="whisper-base-encoder", family="audio", vocab=2,  # unused (embeds in)
    d_model=512, n_layers=6, pattern=(LayerSpec("attn", "dense"),),
    n_heads=8, n_kv=8, head_dim=64, d_ff=2048,
    causal=False, pos_embed="sinusoidal", rope_theta=None,
    norm="layernorm", act="gelu", gated_mlp=False, vocab_pad_multiple=16,
).validate()

FULL = ModelConfig(
    name="whisper-base", family="audio", vocab=51_865,
    d_model=512, n_layers=12,
    pattern=(LayerSpec("attn", "none"), LayerSpec("cross", "dense")),
    n_heads=8, n_kv=8, head_dim=64, d_ff=2048,
    pos_embed="sinusoidal", rope_theta=None,
    norm="layernorm", act="gelu", gated_mlp=False,
    encoder=_ENC, n_frontend_tokens=1500, frontend_dim=512,
    vocab_pad_multiple=256,
).validate()

_SMOKE_ENC = ModelConfig(
    name="whisper-smoke-encoder", family="audio", vocab=2,
    d_model=32, n_layers=2, pattern=(LayerSpec("attn", "dense"),),
    n_heads=4, n_kv=4, head_dim=8, d_ff=64,
    causal=False, pos_embed="sinusoidal", rope_theta=None,
    norm="layernorm", act="gelu", gated_mlp=False, vocab_pad_multiple=16,
).validate()

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio", vocab=128,
    d_model=32, n_layers=4,
    pattern=(LayerSpec("attn", "none"), LayerSpec("cross", "dense")),
    n_heads=4, n_kv=4, head_dim=8, d_ff=64,
    pos_embed="sinusoidal", rope_theta=None,
    norm="layernorm", act="gelu", gated_mlp=False,
    encoder=_SMOKE_ENC, n_frontend_tokens=12, frontend_dim=32,
    vocab_pad_multiple=16,
).validate()
