"""llama-3.2-vision-90b [vlm] — cross-attn image layers
(hf:meta-llama/Llama-3.2-11B-Vision).  100L d8192 64H (GQA kv=8)
d_ff 28672 vocab 128256.  Every 5th layer cross-attends to image patch
embeddings; the vision tower is a stub: ``input_specs`` provides
precomputed [B, 1600, d_model] patch embeddings."""
from repro.configs.common import LayerSpec, ModelConfig

_PATTERN = (LayerSpec("attn", "dense"),) * 4 + (LayerSpec("cross", "dense"),)

FULL = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm", vocab=128_256,
    d_model=8192, n_layers=100, pattern=_PATTERN,
    n_heads=64, n_kv=8, head_dim=128, d_ff=28_672,
    rope_theta=500_000.0,
    n_frontend_tokens=1600, frontend_dim=8192,
).validate()

SMOKE = ModelConfig(
    name="llama32v-smoke", family="vlm", vocab=128,
    d_model=32, n_layers=5, pattern=_PATTERN,
    n_heads=4, n_kv=2, head_dim=8, d_ff=64,
    rope_theta=500_000.0,
    n_frontend_tokens=8, frontend_dim=32, vocab_pad_multiple=16,
).validate()
