"""mamba2-780m [ssm] — SSD, attention-free (arXiv:2405.21060).
48L d1536 ssm_state=128 vocab 50280; d_inner 3072 ⇒ 48 SSD heads of 64.
Sub-quadratic ⇒ runs the long_500k cell."""
from repro.configs.common import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="mamba2-780m", family="ssm", vocab=50_280,
    d_model=1536, n_layers=48, pattern=(LayerSpec("ssd", "none"),),
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    supports_long_context=True,
).validate()

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm", vocab=128,
    d_model=32, n_layers=3, pattern=(LayerSpec("ssd", "none"),),
    ssm_state=16, ssm_headdim=8, ssm_expand=2, ssm_chunk=8,
    supports_long_context=True, vocab_pad_multiple=16,
).validate()
