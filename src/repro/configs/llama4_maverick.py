"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
early-fusion multimodal (hf:meta-llama/Llama-4-Scout-17B-16E).
48L d5120 40H (GQA kv=8) expert d_ff 8192 vocab 202048.
The early-fusion frontend is a stub (vision patches would be interleaved as
ordinary tokens); 40 heads do not divide TP=16 ⇒ sequence-parallel attention."""
from repro.configs.common import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", vocab=202_048,
    d_model=5120, n_layers=48, pattern=(LayerSpec("attn", "moe"),),
    n_heads=40, n_kv=8, head_dim=128, d_ff=8192,
    n_experts=128, top_k=1, capacity_factor=1.25, moe_group_size=4096,
    shared_expert=True, rope_theta=500_000.0,
).validate()

SMOKE = ModelConfig(
    name="llama4-smoke", family="moe", vocab=128,
    d_model=40, n_layers=2, pattern=(LayerSpec("attn", "moe"),),
    n_heads=5, n_kv=5, head_dim=8, d_ff=16,
    n_experts=4, top_k=1, capacity_factor=2.0, moe_group_size=64,
    shared_expert=True, rope_theta=500_000.0, vocab_pad_multiple=16,
).validate()
