"""granite-moe-1b-a400m [moe] — 32 experts top-8
(hf:ibm-granite/granite-3.0-1b-a400m-base).
24L d1024 16H (GQA kv=8) expert d_ff 512 vocab 49155."""
from repro.configs.common import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="granite-moe-1b-a400m", family="moe", vocab=49_155,
    d_model=1024, n_layers=24, pattern=(LayerSpec("attn", "moe"),),
    n_heads=16, n_kv=8, head_dim=64, d_ff=512,
    n_experts=32, top_k=8, capacity_factor=1.25, moe_group_size=4096,
    rope_theta=10_000.0,
).validate()

SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe", vocab=128,
    d_model=32, n_layers=2, pattern=(LayerSpec("attn", "moe"),),
    n_heads=4, n_kv=2, head_dim=8, d_ff=16,
    n_experts=4, top_k=2, capacity_factor=2.0, moe_group_size=64,
    rope_theta=10_000.0, vocab_pad_multiple=16,
).validate()
