"""Small shared utilities: padding, tree paths, PRNG fan-out."""
from __future__ import annotations

import math
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp


def ceil_to(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return ((x + m - 1) // m) * m


def pad_axis(x: jax.Array, axis: int, target: int, value: float = 0.0) -> jax.Array:
    """Pad ``axis`` of ``x`` up to length ``target`` with ``value``."""
    cur = x.shape[axis]
    if cur == target:
        return x
    if cur > target:
        raise ValueError(f"axis {axis} of shape {x.shape} exceeds target {target}")
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - cur)
    return jnp.pad(x, pads, constant_values=value)


def pad_to_multiple(x: jax.Array, axis: int, multiple: int, value: float = 0.0) -> jax.Array:
    return pad_axis(x, axis, ceil_to(x.shape[axis], multiple), value)


def tree_paths(tree: Any) -> list[str]:
    """Flattened '/'-joined key paths for a pytree of dicts/lists."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [path_str(p) for p, _ in flat]


def path_str(path: tuple) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path_str(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """tree_map where ``fn`` receives the '/'-joined path string."""
    return jax.tree_util.tree_map_with_path(lambda p, x: fn(path_str(p), x), tree)


def split_keys(key: jax.Array, names: Iterable[str]) -> dict[str, jax.Array]:
    names = list(names)
    keys = jax.random.split(key, len(names))
    return {n: k for n, k in zip(names, keys)}


def count_params(tree: Any) -> int:
    return sum(int(math.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "shape"))


def cast_tree(tree: Any, dtype) -> Any:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if hasattr(x, "astype") and jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )
