"""Architecture registry: --arch <id> → configs + model API.

Every entry exposes the same functional API (init/forward/lm_logits/
prefill/init_cache/decode_step) regardless of family; whisper dispatches to
the enc-dec composition, everything else to the generic stack.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp

from repro.configs import (gemma2_9b, granite_3_8b, granite_moe_1b,
                           llama32_vision_90b, llama4_maverick, mamba2_780m,
                           qwen2_72b, recurrentgemma_9b, starcoder2_7b,
                           whisper_base)
from repro.configs.common import ModelConfig, SHAPES, ShapeSpec
from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    name: str
    full: ModelConfig
    smoke: ModelConfig
    module: object                      # transformer | encdec

    def config(self, preset: str = "full") -> ModelConfig:
        return self.full if preset == "full" else self.smoke

    # frontend stubs -------------------------------------------------------
    def frontend_shape(self, cfg: ModelConfig, batch: int) -> Optional[dict]:
        if cfg.family == "audio":
            return {"frames": (batch, cfg.n_frontend_tokens, cfg.frontend_dim)}
        if cfg.family == "vlm":
            return {"cross_kv": (batch, cfg.n_frontend_tokens,
                                 cfg.frontend_dim)}
        return None


_CONF = {
    "whisper-base": (whisper_base, encdec),
    "gemma2-9b": (gemma2_9b, transformer),
    "qwen2-72b": (qwen2_72b, transformer),
    "starcoder2-7b": (starcoder2_7b, transformer),
    "granite-3-8b": (granite_3_8b, transformer),
    "llama-3.2-vision-90b": (llama32_vision_90b, transformer),
    "mamba2-780m": (mamba2_780m, transformer),
    "recurrentgemma-9b": (recurrentgemma_9b, transformer),
    "granite-moe-1b-a400m": (granite_moe_1b, transformer),
    "llama4-maverick-400b-a17b": (llama4_maverick, transformer),
}

ARCHS: dict[str, ArchEntry] = {
    name: ArchEntry(name=name, full=mod.FULL, smoke=mod.SMOKE, module=api)
    for name, (mod, api) in _CONF.items()
}


def get(name: str) -> ArchEntry:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cells(include_skips: bool = True):
    """All 40 (arch × shape) cells with skip annotations."""
    out = []
    for name, entry in ARCHS.items():
        for shape in SHAPES.values():
            skip = None
            if shape.name == "long_500k" and not entry.full.supports_long_context:
                skip = "quadratic attention cannot serve 500k context"
            if skip is None or include_skips:
                out.append((name, shape, skip))
    return out
