"""Mamba-2 (SSD — state-space duality) blocks.

The training/prefill path uses the chunked SSD algorithm (Dao & Gu 2024):
within a chunk everything is batched matmuls (MXU-friendly); across chunks a
small ``lax.scan`` carries the [H, P, N] state.  The decode path is the exact
single-step recurrence on the same state, so prefill→decode hand-off is
bit-consistent up to float error (covered by tests against the naive
recurrent oracle).

TP note: projections are kept *separate* (z/x/B/C/dt) rather than fused,
so each output segment is head-aligned and shards cleanly on the ``model``
axis — a fused in_proj would put segment boundaries inside shards and force
GSPMD reshards (DESIGN.md §6).

Shapes: x [B,S,H,P] (P=headdim), B/C [B,S,G,N] (G router groups, N=d_state),
dt [B,S,H], A scalar per head.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.utils import ceil_to, split_keys


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    d_model: int
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim


def ssd_init(key, cfg: SSDConfig) -> dict:
    ks = split_keys(key, ["z", "x", "B", "C", "dtp", "out",
                          "convx", "convb", "convc", "dt"])
    gn = cfg.n_groups * cfg.d_state
    dt = jnp.exp(jax.random.uniform(ks["dt"], (cfg.n_heads,)) *
                 (math.log(cfg.dt_max) - math.log(cfg.dt_min)) +
                 math.log(cfg.dt_min))
    conv = lambda k, c: jax.random.normal(k, (cfg.conv_width, c), jnp.float32) \
        / math.sqrt(cfg.conv_width)
    return {
        "z_proj": L.dense_init(ks["z"], cfg.d_model, cfg.d_inner),
        "x_proj": L.dense_init(ks["x"], cfg.d_model, cfg.d_inner),
        "b_proj": L.dense_init(ks["B"], cfg.d_model, gn),
        "c_proj": L.dense_init(ks["C"], cfg.d_model, gn),
        "dt_proj": L.dense_init(ks["dtp"], cfg.d_model, cfg.n_heads),
        "out_proj": L.dense_init(ks["out"], cfg.d_inner, cfg.d_model),
        "conv_x": {"w": conv(ks["convx"], cfg.d_inner),
                   "b": jnp.zeros((cfg.d_inner,), jnp.float32)},
        "conv_b": {"w": conv(ks["convb"], gn),
                   "b": jnp.zeros((gn,), jnp.float32)},
        "conv_c": {"w": conv(ks["convc"], gn),
                   "b": jnp.zeros((gn,), jnp.float32)},
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),     # inverse softplus
        "A_log": jnp.log(jnp.ones((cfg.n_heads,))),   # A = -1 per head
        "D": jnp.ones((cfg.n_heads,), jnp.float32),
        "norm": L.rmsnorm_init(cfg.d_inner),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv along seq. x [B,S,C], w [K,C].

    With ``state`` [B,K-1,C] (decode), returns (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):, :]
    return jax.nn.silu(y), new_state


def _ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """Chunked SSD scan.

    x [B,S,H,P], dt [B,S,H] (already softplus'ed), A [H] (negative),
    B, C [B,S,G,N].  Returns (y [B,S,H,P], h_final [B,H,P,N]).
    """
    b, s, h, p = x.shape
    g, n = B.shape[-2:]
    chunk = min(chunk, s)        # decode: no padding waste for tiny s
    sp = ceil_to(s, chunk)
    pad = sp - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc, q = sp // chunk, chunk
    rep = h // g                                   # heads per router group

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = jnp.repeat(B.reshape(b, nc, q, g, n), rep, axis=3)   # [B,Nc,Q,H,N]
    Cc = jnp.repeat(C.reshape(b, nc, q, g, n), rep, axis=3)

    dA = dtc * A[None, None, None, :]              # [B,Nc,Q,H] (negative)
    dAcs = jnp.cumsum(dA, axis=2)                  # within-chunk cumsum

    # --- intra-chunk (quadratic in Q, batched matmul) -----------------
    # L[i,j] = exp(dAcs_i − dAcs_j) for i ≥ j else 0
    li = dAcs[:, :, :, None, :]                    # [B,Nc,Q,1,H]
    lj = dAcs[:, :, None, :, :]                    # [B,Nc,1,Q,H]
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    Lmat = jnp.where(mask, jnp.exp(li - lj), 0.0)  # [B,Nc,Q,Q,H]
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc) * Lmat
    xdt = xc * dtc[..., None]                      # [B,Nc,Q,H,P]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt)

    # --- chunk states --------------------------------------------------
    decay_to_end = jnp.exp(dAcs[:, :, -1:, :] - dAcs)      # [B,Nc,Q,H]
    states = jnp.einsum("bcqhn,bcqhp,bcqh->bchpn", Bc, xdt, decay_to_end)

    # --- inter-chunk recurrence ----------------------------------------
    chunk_decay = jnp.exp(dAcs[:, :, -1, :])               # [B,Nc,H]

    def step(hprev, inp):
        st, dec = inp                                       # [B,H,P,N],[B,H]
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h_init = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0
    h_fin, h_prevs = lax.scan(
        step, h_init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)                        # [B,Nc,H,P,N]

    # --- inter-chunk contribution --------------------------------------
    in_decay = jnp.exp(dAcs)                                # [B,Nc,Q,H]
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Cc, h_prevs, in_decay)

    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s]
    return y, h_fin


def ssd_block(params, x: jax.Array, cfg: SSDConfig, *,
              policy: L.Policy = L.Policy(), bfp: L.BFPPolicy = L.NO_BFP,
              state: dict | None = None):
    """Full mamba2 mixer. x [B,S,D] → (y [B,S,D], new_state|None).

    ``state``: {"h": [B,H,P,N], "conv_x"/"conv_b"/"conv_c": [B,K-1,·]}
    enables stateful decode; None = stateless train/prefill.
    """
    b, s, d = x.shape
    cd = policy.compute_dtype
    zgate = L.dense(params["z_proj"], x, policy=policy, bfp=bfp)
    xr = L.dense(params["x_proj"], x, policy=policy, bfp=bfp)
    Br = L.dense(params["b_proj"], x, policy=policy, bfp=bfp)
    Cr = L.dense(params["c_proj"], x, policy=policy, bfp=bfp)
    dt_raw = L.dense(params["dt_proj"], x, policy=policy, bfp=bfp)

    cs = {"conv_x": None, "conv_b": None, "conv_c": None} if state is None \
        else state
    xs, ncx = _causal_conv(xr, params["conv_x"]["w"].astype(cd),
                           params["conv_x"]["b"].astype(cd), cs["conv_x"])
    Bs, ncb = _causal_conv(Br, params["conv_b"]["w"].astype(cd),
                           params["conv_b"]["b"].astype(cd), cs["conv_b"])
    Cs, ncc = _causal_conv(Cr, params["conv_c"]["w"].astype(cd),
                           params["conv_c"]["b"].astype(cd), cs["conv_c"])

    xs = xs.reshape(b, s, cfg.n_heads, cfg.headdim)
    B = Bs.reshape(b, s, cfg.n_groups, cfg.d_state)
    C = Cs.reshape(b, s, cfg.n_groups, cfg.d_state)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])

    xs32, B32, C32 = (t.astype(jnp.float32) for t in (xs, B, C))
    h0 = None if state is None else state["h"]
    y, h_fin = _ssd_chunked(xs32, dt, A, B32, C32, cfg.chunk, h0=h0)
    y = y + xs32 * params["D"][None, None, :, None]

    y = y.reshape(b, s, cfg.d_inner).astype(cd)
    y = L.rmsnorm(params["norm"], y) * jax.nn.silu(zgate)
    out = L.dense(params["out_proj"], y, policy=policy, bfp=bfp)
    new_state = None if state is None else {
        "h": h_fin, "conv_x": ncx, "conv_b": ncb, "conv_c": ncc}
    return out, new_state


def ssd_state_init(cfg: SSDConfig, batch: int, dtype=jnp.float32) -> dict:
    gn = cfg.n_groups * cfg.d_state
    k = cfg.conv_width - 1
    return {
        "h": jnp.zeros((batch, cfg.n_heads, cfg.headdim, cfg.d_state),
                       jnp.float32),
        "conv_x": jnp.zeros((batch, k, cfg.d_inner), dtype),
        "conv_b": jnp.zeros((batch, k, gn), dtype),
        "conv_c": jnp.zeros((batch, k, gn), dtype),
    }


def ssd_reference(x, dt, A, B, C):
    """Naive O(S·N·P) recurrent oracle for tests. Shapes as _ssd_chunked."""
    b, s, h, p = x.shape
    g, n = B.shape[-2:]
    rep = h // g
    Bf = jnp.repeat(B, rep, axis=2)
    Cf = jnp.repeat(C, rep, axis=2)

    def step(hprev, t):
        xt, dtt, Bt, Ct = x[:, t], dt[:, t], Bf[:, t], Cf[:, t]
        dA = jnp.exp(dtt * A[None, :])                        # [B,H]
        hnew = hprev * dA[..., None, None] + jnp.einsum(
            "bhn,bhp,bh->bhpn", Bt, xt, dtt)
        y = jnp.einsum("bhn,bhpn->bhp", Ct, hnew)
        return hnew, y

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hf, ys = lax.scan(step, h0, jnp.arange(s))
    return ys.swapaxes(0, 1), hf                              # [B,S,H,P]
