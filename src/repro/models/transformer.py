"""Generic pattern-driven transformer stack.

Expresses every assigned architecture from a ``ModelConfig``: the repeating
layer pattern is scanned (stacked params ⇒ compact HLO even at 100 layers),
the remainder layers run unrolled.  Three execution paths share the sublayer
implementations:

* ``forward``      — training / scoring (full sequence, optional taps for
                     the Duplex branch, MoE aux-loss accumulation);
* ``prefill``      — forward + KV/state cache construction for serving;
* ``decode_step``  — one-token step updating the cache (ring buffers for
                     sliding-window layers, recurrent states for SSD/LRU).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.common import LayerSpec, ModelConfig
from repro.distributed.ctx import constrain
from repro.models import hybrid, layers as L, moe as moe_mod, ssm
from repro.utils import split_keys

# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def _norm_init(cfg: ModelConfig, d: int) -> dict:
    return L.layernorm_init(d) if cfg.norm == "layernorm" else L.rmsnorm_init(d)


def _norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    return L.layernorm(p, x) if cfg.norm == "layernorm" else L.rmsnorm(p, x)


def _act(cfg: ModelConfig):
    return jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu


def attn_cfg_for(cfg: ModelConfig, spec: LayerSpec) -> L.AttnConfig:
    return L.AttnConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv=cfg.n_kv,
        head_dim=cfg.head_dim,
        qkv_bias=cfg.qkv_bias,
        # cross-attn queries/keys live in different position spaces → no rope
        rope_theta=(cfg.rope_theta
                    if cfg.pos_embed == "rope" and spec.kind != "cross"
                    else None),
        softcap=cfg.softcap_attn,
        window=cfg.window if spec.kind == "local" else None,
        causal=cfg.causal and spec.kind != "cross",
        blockwise_threshold=cfg.blockwise_threshold,
        q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk,
        causal_skip=cfg.causal_skip,
        use_flash=cfg.use_flash and spec.kind == "attn",
    )


def _ssd_cfg(cfg: ModelConfig) -> ssm.SSDConfig:
    return ssm.SSDConfig(
        d_model=cfg.d_model, d_state=cfg.ssm_state, headdim=cfg.ssm_headdim,
        expand=cfg.ssm_expand, conv_width=cfg.conv_width, chunk=cfg.ssm_chunk)


def _lru_cfg(cfg: ModelConfig) -> hybrid.LRUConfig:
    return hybrid.LRUConfig(d_model=cfg.d_model, lru_width=cfg.lru_width,
                            conv_width=cfg.conv_width,
                            scan_chunk=cfg.lru_scan_chunk)


def _moe_cfg(cfg: ModelConfig) -> moe_mod.MoEConfig:
    return moe_mod.MoEConfig(
        d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        group_size=cfg.moe_group_size, gated=cfg.gated_mlp,
        shared_expert=cfg.shared_expert)


def sinusoidal_embed(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) *
                   jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# sublayer init / apply
# --------------------------------------------------------------------------

def _sub_init(key: jax.Array, cfg: ModelConfig, spec: LayerSpec) -> dict:
    ks = split_keys(key, ["mix", "mlp"])
    p: dict = {}
    if spec.kind in ("attn", "local", "cross"):
        p["norm"] = _norm_init(cfg, cfg.d_model)
        p["attn"] = L.attn_init(ks["mix"], attn_cfg_for(cfg, spec))
        if cfg.post_norm:
            p["post_norm"] = _norm_init(cfg, cfg.d_model)
    elif spec.kind == "ssd":
        p["norm"] = _norm_init(cfg, cfg.d_model)
        p["ssd"] = ssm.ssd_init(ks["mix"], _ssd_cfg(cfg))
    elif spec.kind == "lru":
        p["norm"] = _norm_init(cfg, cfg.d_model)
        p["lru"] = hybrid.lru_init(ks["mix"], _lru_cfg(cfg))
    else:
        raise ValueError(spec.kind)

    if spec.mlp == "dense":
        p["mlp_norm"] = _norm_init(cfg, cfg.d_model)
        p["mlp"] = L.mlp_init(ks["mlp"], cfg.d_model, cfg.d_ff,
                              gated=cfg.gated_mlp)
        if cfg.post_norm:
            p["mlp_post_norm"] = _norm_init(cfg, cfg.d_model)
    elif spec.mlp == "moe":
        p["mlp_norm"] = _norm_init(cfg, cfg.d_model)
        p["moe"] = moe_mod.moe_init(ks["mlp"], _moe_cfg(cfg))
        if cfg.post_norm:
            p["mlp_post_norm"] = _norm_init(cfg, cfg.d_model)
    return p


def _apply_mlp(p, h, spec, cfg, policy, bfp):
    """Channel mixer + residual; returns (h, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if spec.mlp == "none":
        return h, aux
    u = _norm(cfg, p["mlp_norm"], h)
    if spec.mlp == "dense":
        y = L.mlp(p["mlp"], u, policy=policy, bfp=bfp, act=_act(cfg))
    else:
        y, aux = moe_mod.moe_apply(p["moe"], u, _moe_cfg(cfg), policy=policy,
                                   bfp=bfp)
    if cfg.post_norm:
        y = _norm(cfg, p["mlp_post_norm"], y)
    return h + y, aux


def _sub_apply(p, h, spec, cfg, *, policy, bfp, cross_kv, positions):
    """Full-sequence sublayer (train / scoring). Returns (h, aux)."""
    acfg = attn_cfg_for(cfg, spec)
    if spec.kind in ("attn", "local", "cross"):
        u = _norm(cfg, p["norm"], h)
        kv = cross_kv if spec.kind == "cross" else None
        y = L.attention_layer(p["attn"], u, acfg, policy=policy, bfp=bfp,
                              kv_x=kv, positions=positions)
        if cfg.post_norm:
            y = _norm(cfg, p["post_norm"], y)
        h = h + y
    elif spec.kind == "ssd":
        u = _norm(cfg, p["norm"], h)
        y, _ = ssm.ssd_block(p["ssd"], u, _ssd_cfg(cfg), policy=policy, bfp=bfp)
        h = h + y
    elif spec.kind == "lru":
        u = _norm(cfg, p["norm"], h)
        y, _ = hybrid.lru_block(p["lru"], u, _lru_cfg(cfg), policy=policy,
                                bfp=bfp)
        h = h + y
    return _apply_mlp(p, h, spec, cfg, policy, bfp)


# --------------------------------------------------------------------------
# top-level params / forward
# --------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    cfg.validate()
    ks = split_keys(key, ["embed", "stack", "rem", "final"])
    params: dict = {
        "embed": L.embed_init(ks["embed"], cfg.vocab, cfg.d_model,
                              pad_to=cfg.vocab_pad_multiple),
        "final_norm": _norm_init(cfg, cfg.d_model),
    }
    if cfg.n_rep:
        def init_rep(k):
            kk = jax.random.split(k, len(cfg.pattern))
            return {f"sub{i}": _sub_init(kk[i], cfg, s)
                    for i, s in enumerate(cfg.pattern)}
        keys = jax.random.split(ks["stack"], cfg.n_rep)
        params["stack"] = jax.vmap(init_rep)(keys)
    if cfg.remainder:
        kk = jax.random.split(ks["rem"], len(cfg.remainder))
        params["rem"] = {f"sub{i}": _sub_init(kk[i], cfg, s)
                         for i, s in enumerate(cfg.remainder)}
    return params


def embed_tokens(params, cfg: ModelConfig, tokens: jax.Array,
                 positions: jax.Array, policy: L.Policy) -> jax.Array:
    h = L.embed_lookup(params["embed"], tokens, policy)
    if cfg.embed_scale:
        h = h * math.sqrt(cfg.d_model)
    if cfg.pos_embed == "sinusoidal":
        h = h + sinusoidal_embed(positions, cfg.d_model).astype(h.dtype)
    return h


def forward(params, cfg: ModelConfig, tokens: jax.Array, *,
            frontend: Optional[dict] = None,
            policy: L.Policy = L.Policy(), bfp: L.BFPPolicy = L.NO_BFP,
            collect_taps: bool = False,
            tap_indices=None, tap_pool: int = 1,
            inputs_embeds: Optional[jax.Array] = None) -> dict:
    """Full-sequence forward. Returns {hidden, taps, aux, emb}.

    Tap memory: with ``tap_indices`` (+ ``tap_pool``) only the selected
    superblocks' hidden states are kept, *pooled inside the scan body* into a
    small carry buffer — [n_sel, B, S/pool, D] instead of [n_rep, B, S, D].
    At pod scale this is the difference between 0.5 GB and 85 GB of tap
    residuals per device (DESIGN.md §3).
    """
    b, s = tokens.shape[:2] if inputs_embeds is None else inputs_embeds.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = (embed_tokens(params, cfg, tokens, positions, policy)
         if inputs_embeds is None else inputs_embeds)
    h = constrain(h, "resid")
    emb = h
    cross_kv = None if frontend is None else frontend.get("cross_kv")

    aux = jnp.zeros((), jnp.float32)
    taps = None
    if cfg.n_rep:
        use_buf = collect_taps and tap_indices is not None
        if use_buf:
            from repro.core.duplex import pool_seq  # local import, no cycle
            idx = jnp.asarray(tap_indices, jnp.int32)
            sp = -(-s // tap_pool)
            tap_buf0 = jnp.zeros((len(tap_indices), b, sp, cfg.d_model),
                                 h.dtype)

        def body(carry, xs):
            if use_buf:
                (h, aux, buf), (p_rep, step_i) = carry, xs
            else:
                (h, aux), p_rep = carry, xs
            for i, spec in enumerate(cfg.pattern):
                h, a = _sub_apply(p_rep[f"sub{i}"], h, spec, cfg,
                                  policy=policy, bfp=bfp, cross_kv=cross_kv,
                                  positions=positions)
                h = constrain(h, "resid")
                aux = aux + a
            if use_buf:
                pooled = pool_seq(h, tap_pool)
                match = (idx == step_i)[:, None, None, None]
                buf = jnp.where(match, pooled[None], buf)
                return (h, aux, buf), None
            return (h, aux), (h if collect_taps else jnp.zeros((), h.dtype))

        if use_buf:
            (h, aux, taps), _ = lax.scan(
                body, (h, aux, tap_buf0),
                (params["stack"], jnp.arange(cfg.n_rep)))
        else:
            (h, aux), tap_out = lax.scan(body, (h, aux), params["stack"])
            if collect_taps:
                taps = tap_out                            # [n_rep,B,S,D]
    for i, spec in enumerate(cfg.remainder):
        h, a = _sub_apply(params["rem"][f"sub{i}"], h, spec, cfg,
                          policy=policy, bfp=bfp, cross_kv=cross_kv,
                          positions=positions)
        aux = aux + a
    h = _norm(cfg, params["final_norm"], h)
    return {"hidden": h, "taps": taps, "aux": aux, "emb": emb}


def lm_logits(params, cfg: ModelConfig, hidden: jax.Array,
              policy: L.Policy = L.Policy()) -> jax.Array:
    return L.unembed_logits(params["embed"], hidden, cfg.vocab, policy,
                            softcap=cfg.softcap_final)


# --------------------------------------------------------------------------
# serving: prefill + decode with caches
# --------------------------------------------------------------------------

def _ring_size(cfg: ModelConfig, spec: LayerSpec, max_len: int) -> int:
    if spec.kind == "local" and cfg.window is not None:
        return min(cfg.window, max_len)
    return max_len


def _sub_cache_zeros(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype, lead: tuple = ()) -> Optional[dict]:
    """Zero-initialized cache for one sublayer (no params needed)."""
    if spec.kind in ("attn", "local"):
        size = _ring_size(cfg, spec, max_len)
        c = {
            "k": jnp.zeros(lead + (batch, size, cfg.n_kv, cfg.head_dim), dtype),
            "v": jnp.zeros(lead + (batch, size, cfg.n_kv, cfg.head_dim), dtype),
            "len": jnp.zeros(lead, jnp.int32),
        }
        if spec.kind == "local":
            c["pos"] = jnp.full(lead + (size,), -1, jnp.int32)
        return c
    if spec.kind == "cross":
        # filled by prefill (projected frontend); zeros as dry-run stand-in
        t = max(cfg.n_frontend_tokens, 1)
        return {
            "k": jnp.zeros(lead + (batch, t, cfg.n_kv, cfg.head_dim), dtype),
            "v": jnp.zeros(lead + (batch, t, cfg.n_kv, cfg.head_dim), dtype),
        }
    if spec.kind == "ssd":
        base = ssm.ssd_state_init(_ssd_cfg(cfg), batch, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.zeros(lead + a.shape, a.dtype), base)
    if spec.kind == "lru":
        base = hybrid.lru_state_init(_lru_cfg(cfg), batch, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.zeros(lead + a.shape, a.dtype), base)
    return None


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Shape-complete zero cache (decode dry-run entry point)."""
    cache: dict = {"stack": {}, "rem": {}}
    for i, spec in enumerate(cfg.pattern):
        c = _sub_cache_zeros(cfg, spec, batch, max_len, dtype,
                             lead=(cfg.n_rep,))
        if c is not None:
            cache["stack"][f"sub{i}"] = c
    for i, spec in enumerate(cfg.remainder):
        c = _sub_cache_zeros(cfg, spec, batch, max_len, dtype)
        if c is not None:
            cache["rem"][f"sub{i}"] = c
    kinds = {s.kind for s in cfg.pattern + cfg.remainder}
    if not kinds & {"attn", "local"}:
        cache["step"] = jnp.zeros((), jnp.int32)  # pure-SSM position counter
    return cache


def _sub_prefill(p, h, spec, cfg, *, policy, cross_kv, positions, max_len,
                 dtype):
    """Sublayer forward that also emits its cache. Returns (h, cache)."""
    acfg = attn_cfg_for(cfg, spec)
    b, s, _ = h.shape
    if spec.kind in ("attn", "local"):
        u = _norm(cfg, p["norm"], h)
        q, k, v = L._project_qkv(p["attn"], u, u, acfg, policy, L.NO_BFP,
                                 positions)
        if s > acfg.blockwise_threshold:
            o = L.blockwise_attention(q, k, v, causal=acfg.causal,
                                      softcap=acfg.softcap, window=acfg.window,
                                      q_chunk=acfg.q_chunk,
                                      kv_chunk=acfg.kv_chunk,
                                      causal_skip=acfg.causal_skip)
        else:
            o = L.full_attention(q, k, v, causal=acfg.causal,
                                 softcap=acfg.softcap, window=acfg.window)
        o = o.reshape(b, s, acfg.n_heads * acfg.head_dim)
        y = L.dense(p["attn"]["wo"], o, policy=policy)
        if cfg.post_norm:
            y = _norm(cfg, p["post_norm"], y)
        h = h + y
        size = _ring_size(cfg, spec, max_len)
        if spec.kind == "local" and size < max_len:
            keep = min(size, s)
            idx = (jnp.arange(s - keep, s) % size)
            kc = jnp.zeros((b, size, cfg.n_kv, cfg.head_dim), dtype)
            vc = jnp.zeros_like(kc)
            kc = kc.at[:, idx].set(k[:, -keep:].astype(dtype))
            vc = vc.at[:, idx].set(v[:, -keep:].astype(dtype))
            pos = jnp.full((size,), -1, jnp.int32).at[idx].set(
                jnp.arange(s - keep, s))
            cache = {"k": kc, "v": vc, "len": jnp.asarray(s, jnp.int32),
                     "pos": pos}
        else:
            kc = jnp.zeros((b, max_len, cfg.n_kv, cfg.head_dim), dtype)
            vc = jnp.zeros_like(kc)
            kc = lax.dynamic_update_slice_in_dim(kc, k.astype(dtype), 0, 1)
            vc = lax.dynamic_update_slice_in_dim(vc, v.astype(dtype), 0, 1)
            cache = {"k": kc, "v": vc, "len": jnp.asarray(s, jnp.int32)}
        h, _ = _apply_mlp(p, h, spec, cfg, policy, L.NO_BFP)
        return h, cache

    if spec.kind == "cross":
        u = _norm(cfg, p["norm"], h)
        y = L.attention_layer(p["attn"], u, acfg, policy=policy, kv_x=cross_kv,
                              positions=positions)
        if cfg.post_norm:
            y = _norm(cfg, p["post_norm"], y)
        h = h + y
        skv = cross_kv.shape[1]
        k = L.dense(p["attn"]["wk"], cross_kv, policy=policy).reshape(
            b, skv, cfg.n_kv, cfg.head_dim)
        v = L.dense(p["attn"]["wv"], cross_kv, policy=policy).reshape(
            b, skv, cfg.n_kv, cfg.head_dim)
        cache = {"k": k.astype(dtype), "v": v.astype(dtype)}
        h, _ = _apply_mlp(p, h, spec, cfg, policy, L.NO_BFP)
        return h, cache

    if spec.kind == "ssd":
        u = _norm(cfg, p["norm"], h)
        c = _ssd_cfg(cfg)
        y, st = ssm.ssd_block(p["ssd"], u, c, policy=policy,
                              state=ssm.ssd_state_init(c, b, dtype))
        h = h + y
        h, _ = _apply_mlp(p, h, spec, cfg, policy, L.NO_BFP)
        return h, st

    if spec.kind == "lru":
        u = _norm(cfg, p["norm"], h)
        c = _lru_cfg(cfg)
        y, st = hybrid.lru_block(p["lru"], u, c, policy=policy,
                                 state=hybrid.lru_state_init(c, b, dtype))
        h = h + y
        h, _ = _apply_mlp(p, h, spec, cfg, policy, L.NO_BFP)
        return h, st
    raise ValueError(spec.kind)


def prefill(params, cfg: ModelConfig, tokens: jax.Array, *,
            frontend: Optional[dict] = None, max_len: int,
            policy: L.Policy = L.Policy(), cache_dtype=jnp.bfloat16,
            logits_mode: str = "all") -> dict:
    """Process a prompt, return {logits, cache} (cache ready for decode).

    ``logits_mode="last"`` (§Perf): unembed only the final position — a
    serving prefill only needs the next-token distribution, and the full
    [B,S,V] logit tensor is a V-wide matmul plus (for data-sharded vocab
    projections) a giant cross-device reduction.
    """
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = embed_tokens(params, cfg, tokens, positions, policy)
    cross_kv = None if frontend is None else frontend.get("cross_kv")

    cache: dict = {"stack": {}, "rem": {}}
    if cfg.n_rep:
        def body(h, p_rep):
            caches = {}
            for i, spec in enumerate(cfg.pattern):
                h, c = _sub_prefill(p_rep[f"sub{i}"], h, spec, cfg,
                                    policy=policy, cross_kv=cross_kv,
                                    positions=positions, max_len=max_len,
                                    dtype=cache_dtype)
                if c is not None:
                    caches[f"sub{i}"] = c
            return h, caches

        h, cache["stack"] = lax.scan(body, h, params["stack"])
    for i, spec in enumerate(cfg.remainder):
        h, c = _sub_prefill(params["rem"][f"sub{i}"], h, spec, cfg,
                            policy=policy, cross_kv=cross_kv,
                            positions=positions, max_len=max_len,
                            dtype=cache_dtype)
        if c is not None:
            cache["rem"][f"sub{i}"] = c
    kinds = {sp.kind for sp in cfg.pattern + cfg.remainder}
    if not kinds & {"attn", "local"}:
        cache["step"] = jnp.asarray(s, jnp.int32)
    h = _norm(cfg, params["final_norm"], h)
    h_out = h[:, -1:] if logits_mode == "last" else h
    logits = lm_logits(params, cfg, h_out, policy)
    return {"logits": logits, "cache": cache, "hidden": h}


def _sub_decode(p, h, spec, cfg, cache, *, policy):
    """One-token sublayer step. Returns (h, new_cache)."""
    acfg = attn_cfg_for(cfg, spec)
    b = h.shape[0]
    if spec.kind in ("attn", "local"):
        u = _norm(cfg, p["norm"], h)
        if spec.kind == "local" and "pos" in cache:
            y, new_cache = _ring_decode(p["attn"], u, cache, acfg, cfg, policy)
        else:
            y, new_cache = L.attention_decode(p["attn"], u, cache, acfg,
                                              policy=policy)
        if cfg.post_norm:
            y = _norm(cfg, p["post_norm"], y)
        h = h + y
        h, _ = _apply_mlp(p, h, spec, cfg, policy, L.NO_BFP)
        return h, new_cache
    if spec.kind == "cross":
        u = _norm(cfg, p["norm"], h)
        q = L.dense(p["attn"]["wq"], u, policy=policy).reshape(
            b, 1, cfg.n_heads, cfg.head_dim)
        o = L.full_attention(q, cache["k"], cache["v"], causal=False,
                             softcap=acfg.softcap)
        y = L.dense(p["attn"]["wo"],
                    o.reshape(b, 1, cfg.n_heads * cfg.head_dim), policy=policy)
        if cfg.post_norm:
            y = _norm(cfg, p["post_norm"], y)
        h = h + y
        h, _ = _apply_mlp(p, h, spec, cfg, policy, L.NO_BFP)
        return h, cache
    if spec.kind == "ssd":
        u = _norm(cfg, p["norm"], h)
        y, st = ssm.ssd_block(p["ssd"], u, _ssd_cfg(cfg), policy=policy,
                              state=cache)
        h = h + y
        h, _ = _apply_mlp(p, h, spec, cfg, policy, L.NO_BFP)
        return h, st
    if spec.kind == "lru":
        u = _norm(cfg, p["norm"], h)
        y, st = hybrid.lru_block(p["lru"], u, _lru_cfg(cfg), policy=policy,
                                 state=cache)
        h = h + y
        h, _ = _apply_mlp(p, h, spec, cfg, policy, L.NO_BFP)
        return h, st
    raise ValueError(spec.kind)


def _ring_decode(p_attn, u, cache, acfg: L.AttnConfig, cfg: ModelConfig,
                 policy):
    """Sliding-window decode over a ring buffer cache."""
    b = u.shape[0]
    cur = cache["len"]
    size = cache["k"].shape[1]
    positions = jnp.full((b, 1), cur, jnp.int32)
    q, k, v = L._project_qkv(p_attn, u, u, acfg, policy, L.NO_BFP, positions)
    slot = cur % size
    kc = lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    vc = lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    pos = lax.dynamic_update_slice_in_dim(
        cache["pos"], cur[None].astype(jnp.int32), slot, axis=0)
    g = acfg.n_heads // acfg.n_kv
    scores = L._softcap(
        L._gqa_scores(q, L.expand_kv(kc, g)) / math.sqrt(acfg.head_dim),
        acfg.softcap)
    scores = constrain(scores, "dec_scores")   # keep ring cache seq-sharded
    valid = (pos >= 0) & (pos <= cur) & (pos > cur - (acfg.window or size))
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    w = constrain(jax.nn.softmax(scores, axis=-1), "dec_scores")
    o = L._gqa_out(w, L.expand_kv(vc, g)).astype(u.dtype)
    y = L.dense(p_attn["wo"], o.reshape(b, 1, acfg.n_heads * acfg.head_dim),
                policy=policy)
    return y, {"k": kc, "v": vc, "len": cur + 1, "pos": pos}


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, cache: dict, *,
                policy: L.Policy = L.Policy()) -> tuple[jax.Array, dict]:
    """One decode step: tokens [B,1] + cache → (logits [B,1,V], new cache).

    The position is taken from the first attention cache's ``len`` (all
    sublayers advance in lockstep); pure-SSM models carry an explicit
    ``step`` counter instead.
    """
    b = tokens.shape[0]
    step = cache.get("step")
    if step is None:
        step = _first_len(cfg, cache)
    positions = jnp.full((b, 1), step, jnp.int32)
    h = embed_tokens(params, cfg, tokens, positions, policy)

    new_cache: dict = {"stack": {}, "rem": {}}
    if cfg.n_rep:
        def body(h, inp):
            p_rep, c_rep = inp
            new_c = {}
            for i, spec in enumerate(cfg.pattern):
                key = f"sub{i}"
                sub_c = c_rep.get(key)
                h, nc = _sub_decode(p_rep[key], h, spec, cfg, sub_c,
                                    policy=policy)
                if nc is not None:
                    new_c[key] = nc
            return h, new_c

        h, new_cache["stack"] = lax.scan(body, h,
                                         (params["stack"], cache["stack"]))
    for i, spec in enumerate(cfg.remainder):
        key = f"sub{i}"
        h, nc = _sub_decode(params["rem"][key], h, spec, cfg,
                            cache["rem"].get(key), policy=policy)
        if nc is not None:
            new_cache["rem"][key] = nc
    if "step" in cache:
        new_cache["step"] = step + 1
    h = _norm(cfg, params["final_norm"], h)
    logits = lm_logits(params, cfg, h, policy)
    return logits, new_cache


def _first_len(cfg: ModelConfig, cache: dict):
    for i, spec in enumerate(cfg.pattern):
        if spec.kind in ("attn", "local"):
            return cache["stack"][f"sub{i}"]["len"][0]
    for i, spec in enumerate(cfg.remainder):
        if spec.kind in ("attn", "local"):
            return cache["rem"][f"sub{i}"]["len"]
    raise ValueError("no attention cache; provide cache['step']")
