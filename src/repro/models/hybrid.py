"""RG-LRU recurrent block (Griffin / RecurrentGemma).

    r_t = σ(W_r x_t)                 (recurrence gate)
    i_t = σ(W_i x_t)                 (input gate)
    log a_t = −c · softplus(Λ) · r_t
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill uses ``lax.associative_scan`` (log-depth, parallel over
seq); decode is the exact one-step recurrence on the carried state.
The enclosing recurrent block is Griffin's: depthwise causal conv on the
recurrent branch, GeLU gate branch, elementwise merge, output projection.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.ssm import _causal_conv
from repro.utils import split_keys

_C = 8.0  # Griffin's fixed exponent scale


@dataclasses.dataclass(frozen=True)
class LRUConfig:
    d_model: int
    lru_width: int
    conv_width: int = 4
    # §Perf H2: bound associative-scan temporaries to O(chunk) by scanning
    # chunk-by-chunk with a carried state (None = single full-length scan).
    scan_chunk: int | None = None


def lru_init(key, cfg: LRUConfig) -> dict:
    ks = split_keys(key, ["wx", "wy", "wo", "conv", "wr", "wi", "lam"])
    w = cfg.lru_width
    # Λ init so a ∈ (0.9, 0.999) at r=1 (Griffin appendix)
    u = jax.random.uniform(ks["lam"], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))       # inverse of a=exp(-c·sp(Λ))
    return {
        "wx": L.dense_init(ks["wx"], cfg.d_model, w),
        "wy": L.dense_init(ks["wy"], cfg.d_model, w),
        "wo": L.dense_init(ks["wo"], w, cfg.d_model),
        "conv_w": jax.random.normal(ks["conv"], (cfg.conv_width, w),
                                    jnp.float32) / math.sqrt(cfg.conv_width),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "wr": L.dense_init(ks["wr"], w, w, bias=True, scale=0.02),
        "wi": L.dense_init(ks["wi"], w, w, bias=True, scale=0.02),
        "lambda": lam,
    }


def _combine(u, v):
    a1, b1 = u
    a2, b2 = v
    return a2 * a1, a2 * b1 + b2


def _rg_lru(params, x: jax.Array, policy: L.Policy, h0=None,
            scan_chunk: int | None = None):
    """x: [B,S,W] → (y [B,S,W] f32, h_final [B,W] f32)."""
    from repro.distributed.ctx import constrain
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(constrain(
        L.dense(params["wr"], x, policy=policy), "act_lru")
        .astype(jnp.float32))
    i = jax.nn.sigmoid(constrain(
        L.dense(params["wi"], x, policy=policy), "act_lru")
        .astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lambda"])[None, None, :] * r
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12)) * i * x32

    if x.shape[1] == 1 and h0 is not None:            # decode fast path
        h = a[:, 0] * h0 + gated_x[:, 0]
        return h[:, None, :], h

    b, s, w = x.shape
    if scan_chunk is None or scan_chunk >= s:
        if h0 is not None:
            # fold the carried state in as a virtual step-0 contribution
            gated_x = gated_x.at[:, 0].add(a[:, 0] * h0)
        _, acc_b = lax.associative_scan(_combine, (a, gated_x), axis=1)
        return acc_b, acc_b[:, -1]

    # §Perf H2: chunked scan — log-depth within a chunk, sequential carry
    # across chunks; temporaries are O(B·chunk·W) instead of O(B·S·W).
    from repro.utils import ceil_to
    sp = ceil_to(s, scan_chunk)
    if sp != s:
        a = jnp.pad(a, ((0, 0), (0, sp - s), (0, 0)), constant_values=1.0)
        gated_x = jnp.pad(gated_x, ((0, 0), (0, sp - s), (0, 0)))
    nc = sp // scan_chunk
    ac = a.reshape(b, nc, scan_chunk, w).swapaxes(0, 1)
    gc = gated_x.reshape(b, nc, scan_chunk, w).swapaxes(0, 1)

    def chunk_step(h, inp):
        a_i, g_i = inp                                 # [B,chunk,W]
        acc_a, acc_b = lax.associative_scan(_combine, (a_i, g_i), axis=1)
        y = acc_b + acc_a * h[:, None, :]              # fold carried state
        return y[:, -1], y

    h_init = jnp.zeros((b, w), jnp.float32) if h0 is None else h0
    h_fin, ys = lax.scan(chunk_step, h_init, (ac, gc))
    y = ys.swapaxes(0, 1).reshape(b, sp, w)[:, :s]
    return y, y[:, -1]


def lru_block(params, x: jax.Array, cfg: LRUConfig, *,
              policy: L.Policy = L.Policy(), bfp: L.BFPPolicy = L.NO_BFP,
              state: dict | None = None):
    """Griffin recurrent block. x [B,S,D] → (y [B,S,D], new_state|None)."""
    cd = policy.compute_dtype
    gate = jax.nn.gelu(L.dense(params["wy"], x, policy=policy, bfp=bfp))
    rec = L.dense(params["wx"], x, policy=policy, bfp=bfp)
    conv_state = None if state is None else state["conv"]
    rec, new_conv = _causal_conv(rec, params["conv_w"].astype(cd),
                                 params["conv_b"].astype(cd), conv_state)
    h0 = None if state is None else state["h"]
    y, h_fin = _rg_lru(params, rec, policy, h0=h0,
                       scan_chunk=cfg.scan_chunk)
    out = L.dense(params["wo"], y.astype(cd) * gate, policy=policy, bfp=bfp)
    new_state = None if state is None else {"h": h_fin, "conv": new_conv}
    return out, new_state


def lru_state_init(cfg: LRUConfig, batch: int, dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
    }


def rg_lru_reference(params, x, policy: L.Policy, h0=None):
    """Naive per-step recurrence oracle for tests."""
    r = jax.nn.sigmoid(L.dense(params["wr"], x, policy=policy)
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(L.dense(params["wi"], x, policy=policy)
                       .astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lambda"])[None, None, :] * r
    a = jnp.exp(log_a)
    gx = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12)) * i \
        * x.astype(jnp.float32)

    def step(h, t):
        h = a[:, t] * h + gx[:, t]
        return h, h

    b, s, w = x.shape
    h_init = jnp.zeros((b, w), jnp.float32) if h0 is None else h0
    hf, ys = lax.scan(step, h_init, jnp.arange(s))
    return ys.swapaxes(0, 1), hf
