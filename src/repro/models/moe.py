"""GShard-style Mixture-of-Experts layer (dropped tokens, capacity factor).

Expert-parallel by construction: the dispatch/combine einsums carry an
explicit expert axis that the sharding rules place on the ``model`` mesh axis
(EP), so GSPMD materializes the all-to-all exchange between the token-sharded
and expert-sharded layouts.  Tokens are processed in fixed-size groups so the
dispatch tensors stay bounded: ``[G, g, E, C]`` with ``C ≈ g·k/E·cf``.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.utils import ceil_to, split_keys


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 1024
    gated: bool = True
    shared_expert: bool = False   # llama4-style always-on expert


def moe_init(key, cfg: MoEConfig) -> dict:
    ks = split_keys(key, ["router", "wi", "wg", "wo", "shared"])
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": L.dense_init(ks["router"], d, e, scale=0.02),
        "wi": jax.random.normal(ks["wi"], (e, d, f), jnp.float32) * scale,
        "wo": jax.random.normal(ks["wo"], (e, f, d), jnp.float32) / math.sqrt(f),
    }
    if cfg.gated:
        p["wg"] = jax.random.normal(ks["wg"], (e, d, f), jnp.float32) * scale
    if cfg.shared_expert:
        p["shared"] = L.mlp_init(ks["shared"], d, f, gated=cfg.gated)
    return p


def capacity(cfg: MoEConfig, group: int) -> int:
    c = int(math.ceil(group * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(4, ceil_to(c, 4))


def moe_apply(params, x: jax.Array, cfg: MoEConfig, *,
              policy: L.Policy = L.Policy(), bfp: L.BFPPolicy = L.NO_BFP):
    """x: [B,S,D] → (y [B,S,D], aux_loss scalar)."""
    b, s, d = x.shape
    cd = policy.compute_dtype
    t = b * s
    g = min(cfg.group_size, t)
    tp = ceil_to(t, g)
    xt = x.reshape(t, d)
    if tp != t:
        xt = jnp.pad(xt, ((0, tp - t), (0, 0)))
    xg = xt.reshape(tp // g, g, d)                     # [G,g,D]
    n_groups = tp // g

    logits = L.dense(params["router"], xg, policy=policy).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)            # [G,g,E]

    # load-balancing aux loss (Switch/GShard): E · Σ_e f_e · P_e
    density = jnp.mean(gates, axis=1)                  # [G,E] mean router prob
    top1 = jax.nn.one_hot(jnp.argmax(gates, -1), cfg.n_experts)
    frac = jnp.mean(top1, axis=1)                      # [G,E] token fraction
    aux = cfg.n_experts * jnp.mean(jnp.sum(density * frac, axis=-1))

    cap = capacity(cfg, g)
    remaining = gates
    counts = jnp.zeros((n_groups, 1, cfg.n_experts), jnp.float32)
    dispatch = jnp.zeros((n_groups, g, cfg.n_experts, cap), cd)
    combine = jnp.zeros((n_groups, g, cfg.n_experts, cap), cd)
    for _ in range(cfg.top_k):
        idx = jnp.argmax(remaining, axis=-1)           # [G,g]
        gate_k = jnp.take_along_axis(remaining, idx[..., None], -1)[..., 0]
        onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32)
        # position of each token within its expert's capacity buffer
        pos = jnp.cumsum(onehot, axis=1) - 1.0 + counts  # [G,g,E]
        counts = counts + jnp.sum(onehot, axis=1, keepdims=True)
        keep = (pos < cap) & (onehot > 0)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        d_k = (pos_oh * keep[..., None]).astype(cd)    # [G,g,E,C]
        dispatch = dispatch + d_k
        combine = combine + d_k * gate_k[..., None, None].astype(cd)
        remaining = remaining * (1.0 - onehot)

    # normalize the kept top-k gates to sum to 1 per token
    denom = jnp.sum(combine, axis=(-2, -1), keepdims=True)
    combine = combine / jnp.maximum(denom, 1e-9)

    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xg.astype(cd))  # [E,G,C,D]
    wi = bfp.q(params["wi"]).astype(cd)
    wo = bfp.q(params["wo"]).astype(cd)
    h = jnp.einsum("egcd,edf->egcf", xe, wi)
    if "wg" in params:
        wg = bfp.q(params["wg"]).astype(cd)
        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, wg)) * h
    else:
        h = jax.nn.silu(h)
    ye = jnp.einsum("egcf,efd->egcd", h, wo)            # [E,G,C,D]
    y = jnp.einsum("gsec,egcd->gsd", combine, ye)       # [G,g,D]

    y = y.reshape(tp, d)[:t].reshape(b, s, d)
    if "shared" in params:
        y = y + L.mlp(params["shared"], x, policy=policy, bfp=bfp)
    return y.astype(x.dtype), aux
