"""repro.models"""
