"""Shared model primitives: dense (optionally 2D-BFP), norms, embeddings,
RoPE, MLPs, and the attention cores (full / blockwise / local-window /
cross / decode-with-cache).

Conventions
-----------
* activations are ``[B, S, D]``; attention heads ``[B, S, H, hd]``.
* params are plain dicts of fp32 master arrays; every apply casts to the
  policy compute dtype at the point of use (mixed precision, DESIGN.md §2).
* 2D-BFP training quantization enters exclusively through ``dense`` — the
  paper quantizes matrix operands at matmul boundaries (Table I).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bfp as bfp_mod
from repro.utils import ceil_to, split_keys


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class BFPPolicy:
    """Fake-quant (STE) 2D BFP applied to matmul operands during training."""
    enabled: bool = False
    group: Tuple[int, int] = bfp_mod.PAPER_GROUP
    ebits: int = bfp_mod.PAPER_EBITS
    mbits: int = bfp_mod.PAPER_MBITS

    def q(self, x: jax.Array) -> jax.Array:
        if not self.enabled:
            return x
        shape = x.shape
        x2 = x.reshape(-1, shape[-1]) if x.ndim != 2 else x
        out = bfp_mod.bfp_qdq(x2, self.group, self.ebits, self.mbits)
        return out.reshape(shape)


NO_BFP = BFPPolicy(enabled=False)


# --------------------------------------------------------------------------
# dense / norms / embeddings
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, bias: bool = False,
               scale: float | None = None) -> dict:
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p: dict, x: jax.Array, *, policy: Policy = Policy(),
          bfp: BFPPolicy = NO_BFP) -> jax.Array:
    cd = policy.compute_dtype
    w = bfp.q(p["w"]).astype(cd)
    y = jnp.matmul(bfp.q(x).astype(cd), w)
    if "b" in p:
        y = y + p["b"].astype(cd)
    return y


def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


def embed_init(key, vocab: int, d: int, pad_to: int = 1) -> dict:
    vp = ceil_to(vocab, pad_to)
    return {"table": jax.random.normal(key, (vp, d), jnp.float32) * 0.02}


def embed_lookup(p: dict, tokens: jax.Array, policy: Policy = Policy()) -> jax.Array:
    return p["table"].astype(policy.compute_dtype)[tokens]


def unembed_logits(p: dict, x: jax.Array, vocab: int,
                   policy: Policy = Policy(), softcap: float | None = None):
    """Tied unembedding with padded-vocab masking (padded rows → -inf)."""
    logits = jnp.matmul(x.astype(policy.compute_dtype),
                        p["table"].astype(policy.compute_dtype).T)
    logits = logits.astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    vp = p["table"].shape[0]
    if vp != vocab:
        mask = jnp.arange(vp) < vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding, split-half convention. x: [B,S,H,hd], positions [B,S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq       # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention cores
# --------------------------------------------------------------------------

def _softcap(scores: jax.Array, cap: float | None) -> jax.Array:
    return cap * jnp.tanh(scores / cap) if cap is not None else scores


def expand_kv(k: jax.Array, g: int) -> jax.Array:
    """GQA expansion [B,S,KV,hd] → [B,S,KV·g,hd].

    Flat-head layout is deliberate: the query-head axis H = KV·g shards over
    the TP axis even when KV < TP (k/v stay replicated at KV heads; each
    shard expands only its own heads).  A nested [KV, g] layout would leave
    GSPMD nothing shardable and it starts splitting head_dim instead.
    """
    return jnp.repeat(k, g, axis=2) if g > 1 else k


def _gqa_scores(q, k):
    """q: [B,Sq,H,hd] k: [B,Skv,H,hd] (expanded) → [B,H,Sq,Skv] (f32).

    Softcapping is applied by callers AFTER the 1/√d scale (gemma2
    semantics: cap·tanh(s/√d/cap))."""
    return jnp.einsum("bqhe,bkhe->bhqk", q.astype(jnp.float32),
                      k.astype(jnp.float32))


def _gqa_out(w, v):
    """w: [B,H,Sq,Skv] v: [B,Skv,H,hd] (expanded) → [B,Sq,H,hd]."""
    return jnp.einsum("bhqk,bkhe->bqhe", w, v.astype(jnp.float32))


def full_attention(q, k, v, *, causal: bool, softcap=None,
                   window: int | None = None):
    """Materialized-scores attention (short sequences).

    q: [B,Sq,H,hd]; k, v: [B,Skv,KV,hd] (expanded internally for GQA).
    Returns [B,Sq,H,hd] in q.dtype.
    """
    b, sq, h, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    k = expand_kv(k, h // nkv)
    v = expand_kv(v, h // nkv)
    scores = _softcap(_gqa_scores(q, k) / math.sqrt(hd), softcap)
    qpos, kpos = jnp.arange(sq), jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(w, v).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal: bool, softcap=None,
                        window: int | None = None,
                        q_chunk: int = 512, kv_chunk: int = 1024,
                        causal_skip: bool = False):
    """Flash-style online-softmax attention via lax.scan over chunks.

    Memory is O(Sq·kv_chunk) instead of O(Sq·Skv).

    ``causal_skip`` (perf knob, §Perf): query chunk i only *executes* kv
    chunks that intersect its mask (via lax.cond), eliminating the ~2×
    masked-FLOP waste of the naive schedule for causal, and the O(S/w)×
    waste for sliding-window masks.  Off by default = the paper-agnostic
    baseline schedule.

    q: [B,Sq,H,hd]; k, v: [B,Skv,KV,hd].  GQA expansion happens *per kv
    chunk inside the loop* — expanding the whole cache up front would
    materialize (and re-slice) an H/KV-times larger buffer (§Perf H3).
    """
    b, sq, h, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g_rep = h // nkv
    sq_p, skv_p = ceil_to(sq, q_chunk), ceil_to(skv, kv_chunk)
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    nq, nkv_chunks = sq_p // q_chunk, skv_p // kv_chunk
    scale = 1.0 / math.sqrt(hd)

    qs = qp.reshape(b, nq, q_chunk, h, hd).swapaxes(0, 1)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        q_pos = iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kv_idx):
            acc, m, l = carry
            start = kv_idx * kv_chunk
            kb = expand_kv(
                lax.dynamic_slice_in_dim(kp, start, kv_chunk, axis=1), g_rep)
            vb = expand_kv(
                lax.dynamic_slice_in_dim(vp, start, kv_chunk, axis=1), g_rep)
            s = _softcap(_gqa_scores(qi, kb) * scale, softcap)  # [B,H,qc,kc]
            k_pos = start + jnp.arange(kv_chunk)
            mask = k_pos[None, :] < skv                   # padding
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhe->bhqe", p, vb.astype(jnp.float32))
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, h, q_chunk, hd), jnp.float32)
        m0 = jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        carry0 = (acc0, m0, l0)

        needs_skip = causal_skip and (causal or window is not None)
        if needs_skip:
            # chunk-range bounds that intersect this query chunk's mask
            hi = jnp.minimum(
                (iq * q_chunk + q_chunk + kv_chunk - 1) // kv_chunk, nkv_chunks) \
                if causal else nkv_chunks
            lo = jnp.maximum((iq * q_chunk - window) // kv_chunk, 0) \
                if window is not None else 0

            def guarded(carry, j):
                in_range = jnp.logical_and(j >= lo, j < hi)
                return lax.cond(in_range,
                                lambda c: kv_step(c, j)[0],
                                lambda c: c, carry), None

            (acc, m, l), _ = lax.scan(guarded, carry0, jnp.arange(nkv_chunks))
        else:
            (acc, m, l), _ = lax.scan(kv_step, carry0, jnp.arange(nkv_chunks))
        l = jnp.maximum(l, 1e-30)
        out = (acc / l[..., None])                       # [B,H,qc,hd]
        return None, out.transpose(0, 2, 1, 3)           # [B,qc,H,hd]

    _, outs = lax.scan(q_step, None, (qs, jnp.arange(nq)))
    out = outs.swapaxes(0, 1).reshape(b, sq_p, h, hd)[:, :sq]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, *, softcap=None,
                     window: int | None = None):
    """Single-token decode over a [B,Smax,KV,hd] cache. q: [B,1,H,hd].

    The score constraint (§Perf H4) keeps the KV-cache's sequence sharding
    alive through the mask/softmax: without it GSPMD all-gathers the entire
    cache per token (84 GiB/step for gemma2 decode_32k); with it only the
    online-softmax statistics and the [B,1,H,hd] output cross devices.
    """
    from repro.distributed.ctx import constrain
    b, sq, h, hd = q.shape
    smax, nkv = k_cache.shape[1], k_cache.shape[2]
    kc = expand_kv(k_cache, h // nkv)
    vc = expand_kv(v_cache, h // nkv)
    scores = _softcap(_gqa_scores(q, kc) / math.sqrt(hd), softcap)
    scores = constrain(scores, "dec_scores")              # [B,H,1,Smax]
    kpos = jnp.arange(smax)
    mask = kpos < cur_len                                 # [Smax]
    if window is not None:
        mask &= kpos > (cur_len - 1 - window)
    scores = jnp.where(mask, scores, -1e30)
    scores = constrain(scores, "dec_scores")
    w = jax.nn.softmax(scores, axis=-1)
    w = constrain(w, "dec_scores")
    return _gqa_out(w, vc).astype(q.dtype)


# --------------------------------------------------------------------------
# attention layer (proj + rope + core + out-proj), GQA with KV cache
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float | None = 10000.0   # None → no rope (e.g. whisper enc)
    softcap: float | None = None
    window: int | None = None            # sliding window (local attention)
    causal: bool = True
    blockwise_threshold: int = 1024      # switch to online-softmax above this
    q_chunk: int = 512
    kv_chunk: int = 1024
    causal_skip: bool = False            # §Perf: skip fully-masked kv chunks
    # fused Pallas flash kernel (TPU runtime; interpret=True on CPU tests).
    # Scores/softmax state stay in VMEM — see EXPERIMENTS.md §Perf H3.
    use_flash: bool = False
    flash_interpret: bool = False


def attn_init(key, cfg: AttnConfig) -> dict:
    ks = split_keys(key, ["wq", "wk", "wv", "wo"])
    return {
        "wq": dense_init(ks["wq"], cfg.d_model, cfg.n_heads * cfg.head_dim,
                         cfg.qkv_bias),
        "wk": dense_init(ks["wk"], cfg.d_model, cfg.n_kv * cfg.head_dim,
                         cfg.qkv_bias),
        "wv": dense_init(ks["wv"], cfg.d_model, cfg.n_kv * cfg.head_dim,
                         cfg.qkv_bias),
        "wo": dense_init(ks["wo"], cfg.n_heads * cfg.head_dim, cfg.d_model),
    }


def _project_qkv(p, x, kv_x, cfg: AttnConfig, policy, bfp, positions,
                 kv_positions=None):
    """q: [B,S,H,hd] (flat heads, TP-shardable); k/v: [B,Skv,KV,hd]."""
    from repro.distributed.ctx import constrain
    b, s, _ = x.shape
    q = dense(p["wq"], x, policy=policy, bfp=bfp).reshape(
        b, s, cfg.n_heads, cfg.head_dim)
    skv = kv_x.shape[1]
    k = dense(p["wk"], kv_x, policy=policy, bfp=bfp).reshape(
        b, skv, cfg.n_kv, cfg.head_dim)
    v = dense(p["wv"], kv_x, policy=policy, bfp=bfp).reshape(
        b, skv, cfg.n_kv, cfg.head_dim)
    if cfg.rope_theta is not None and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        kv_pos = positions if kv_positions is None else kv_positions
        k = rope(k, kv_pos, cfg.rope_theta)
    return constrain(q, "act_q"), constrain(k, "act_kv"), constrain(v, "act_kv")


def attention_layer(p, x, cfg: AttnConfig, *, policy=Policy(), bfp=NO_BFP,
                    kv_x=None, positions=None, kv_positions=None):
    """Full-sequence attention (train / prefill).  kv_x ≠ None → cross-attn."""
    b, s, _ = x.shape
    self_attn = kv_x is None
    kv_x = x if self_attn else kv_x
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, x, kv_x, cfg, policy, bfp, positions, kv_positions)
    causal = cfg.causal and self_attn
    if cfg.use_flash and cfg.window is None:
        from repro.kernels.flash_attention import flash_attention
        qc = min(cfg.q_chunk, s)
        kc = min(cfg.kv_chunk, kv_x.shape[1])
        o = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, softcap=cfg.softcap,
            q_chunk=qc, kv_chunk=kc,
            interpret=cfg.flash_interpret).transpose(0, 2, 1, 3)
    elif max(s, kv_x.shape[1]) > cfg.blockwise_threshold:
        o = blockwise_attention(q, k, v, causal=causal, softcap=cfg.softcap,
                                window=cfg.window, q_chunk=cfg.q_chunk,
                                kv_chunk=cfg.kv_chunk,
                                causal_skip=cfg.causal_skip)
    else:
        o = full_attention(q, k, v, causal=causal, softcap=cfg.softcap,
                           window=cfg.window)
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return dense(p["wo"], o, policy=policy, bfp=bfp)


def attention_decode(p, x, cache: dict, cfg: AttnConfig, *, policy=Policy()):
    """One-token decode step; cache = {"k","v": [B,Smax,KV,hd], "len": int32}."""
    b, s, _ = x.shape
    assert s == 1, "decode step processes one token"
    cur = cache["len"]
    positions = jnp.full((b, 1), cur, dtype=jnp.int32)
    q, k, v = _project_qkv(p, x, x, cfg, policy, NO_BFP, positions)
    k_cache = lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), cur, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), cur, axis=1)
    o = decode_attention(q, k_cache, v_cache, cur + 1, softcap=cfg.softcap,
                         window=cfg.window)
    o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    out = dense(p["wo"], o, policy=policy)
    new_cache = {"k": k_cache, "v": v_cache, "len": cur + 1}
    return out, new_cache


def attn_cache_init(cfg: AttnConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv, cfg.head_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, gated: bool = True) -> dict:
    ks = split_keys(key, ["wi", "wg", "wo"])
    p = {"wi": dense_init(ks["wi"], d_model, d_ff),
         "wo": dense_init(ks["wo"], d_ff, d_model)}
    if gated:
        p["wg"] = dense_init(ks["wg"], d_model, d_ff)
    return p


def mlp(p: dict, x: jax.Array, *, policy=Policy(), bfp=NO_BFP,
        act=jax.nn.silu) -> jax.Array:
    h = dense(p["wi"], x, policy=policy, bfp=bfp)
    if "wg" in p:
        h = act(dense(p["wg"], x, policy=policy, bfp=bfp)) * h
    else:
        h = act(h)
    return dense(p["wo"], h, policy=policy, bfp=bfp)
