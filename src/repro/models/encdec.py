"""Encoder-decoder composition (whisper family).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, n_frames, d_model].  The encoder
is a bidirectional stack; the decoder is a causal stack whose pattern
interleaves self-attention and cross-attention to the encoder output.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.common import ModelConfig
from repro.models import layers as L, transformer as T
from repro.utils import split_keys


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    assert cfg.encoder is not None, "enc-dec config needs cfg.encoder"
    ks = split_keys(key, ["enc", "dec"])
    return {
        "encoder": T.init_params(ks["enc"], cfg.encoder),
        "decoder": T.init_params(ks["dec"], cfg),
    }


def encode(params, cfg: ModelConfig, frames: jax.Array, *,
           policy: L.Policy = L.Policy()) -> jax.Array:
    """frames: [B, n_frames, d_model] stub frontend embeddings → enc hidden."""
    ecfg = cfg.encoder
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = frames.astype(policy.compute_dtype)
    if ecfg.pos_embed == "sinusoidal":
        h = h + T.sinusoidal_embed(pos, ecfg.d_model).astype(h.dtype)
    out = T.forward(params["encoder"], ecfg, tokens=jnp.zeros((b, s), jnp.int32),
                    policy=policy, inputs_embeds=h)
    return out["hidden"]


def forward(params, cfg: ModelConfig, tokens: jax.Array, *,
            frontend: dict, policy: L.Policy = L.Policy(),
            bfp: L.BFPPolicy = L.NO_BFP, collect_taps: bool = False,
            tap_indices=None, tap_pool: int = 1) -> dict:
    enc_out = encode(params, cfg, frontend["frames"], policy=policy)
    return T.forward(params["decoder"], cfg, tokens,
                     frontend={"cross_kv": enc_out}, policy=policy, bfp=bfp,
                     collect_taps=collect_taps, tap_indices=tap_indices,
                     tap_pool=tap_pool)


def lm_logits(params, cfg: ModelConfig, hidden, policy=L.Policy()):
    return T.lm_logits(params["decoder"], cfg, hidden, policy)


def prefill(params, cfg: ModelConfig, tokens: jax.Array, *, frontend: dict,
            max_len: int, policy: L.Policy = L.Policy(),
            cache_dtype=jnp.bfloat16, logits_mode: str = "all") -> dict:
    enc_out = encode(params, cfg, frontend["frames"], policy=policy)
    return T.prefill(params["decoder"], cfg, tokens,
                     frontend={"cross_kv": enc_out}, max_len=max_len,
                     policy=policy, cache_dtype=cache_dtype,
                     logits_mode=logits_mode)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    return T.init_cache(cfg, batch, max_len, dtype)


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, cache: dict, *,
                policy: L.Policy = L.Policy()):
    return T.decode_step(params["decoder"], cfg, tokens, cache, policy=policy)
