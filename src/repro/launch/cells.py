"""Cell construction shared by the dry-run, launchers, and benchmarks.

Importing this module never mutates XLA flags or jax device state (unlike
``launch.dryrun``, whose first import line forces 512 host devices).
"""
from __future__ import annotations

import dataclasses as dc
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.common import SHAPES, ShapeSpec
from repro.core import duplex as dx
from repro.distributed import sharding as sh
from repro.models import layers as L, registry
from repro.optim import SGDConfig
from repro.train import serve_step as ss, train_step as ts

POLICY = L.Policy(param_dtype=jnp.float32, compute_dtype=jnp.bfloat16)


def duplex_tcfg(cfg, backbone_dtype=jnp.bfloat16) -> ts.TrainConfig:
    """Production duplex config: branch width scales with the backbone.

    ``backbone_dtype=float8_e4m3fn`` (§Perf H1 iter-3): the frozen backbone
    is *storage*-quantized to 8 bits — the paper stores every tensor in
    ≤6.44-bit BFP (§III-E); fp8 is the closest native-dtype analogue — so
    FSDP weight gathers move half the bytes of bf16.  Compute still upcasts
    to bf16 at use.
    """
    d_branch = max(256, cfg.d_model // 8)
    n_blocks = max(2, min(8, cfg.n_rep))
    return ts.TrainConfig(
        mode="duplex",
        duplex=dx.DuplexConfig(
            n_blocks=n_blocks, d_branch=d_branch, pool_factor=16,
            branch_heads=max(4, d_branch // 128),
            bfp=L.BFPPolicy(enabled=True, group=(32, 32))),
        opt=SGDConfig(), lr=1e-3, backbone_dtype=backbone_dtype)


def activation_rules(cfg, mesh, fsdp_pure: bool = False) -> dict:
    """Per-arch activation PartitionSpecs (DESIGN.md §6).

    Heads divide TP → shard the flat query-head axis; otherwise fall back to
    sequence parallelism (q sharded on seq, kv replicated and all-gathered).
    ``fsdp_pure`` (§Perf H1): the batch dim spreads over ALL mesh axes and
    nothing else is sharded — per-layer TP psums vanish.
    """
    tp = mesh.shape["model"]
    if fsdp_pure:
        dpm = sh.dp_axes(mesh, include_model=True)
        return {"resid": P(dpm, None, None),
                "act_q": P(dpm, None, None, None),
                "act_kv": P(dpm, None, None, None),
                "act_lru": P(dpm, None, None)}
    dp = sh.dp_axes(mesh)
    rules = {"resid": P(dp, None, None),
             "act_lru": P(dp, None, "model"),
             # decode scores follow the seq-sharded KV cache (§Perf H4):
             # without this GSPMD all-gathers the whole cache per token
             "dec_scores": P(dp, None, None, "model")}
    if cfg.n_heads and cfg.n_heads % tp == 0:
        rules["act_q"] = P(dp, None, "model", None)
        rules["act_kv"] = P(dp, None,
                            "model" if cfg.n_kv % tp == 0 else None, None)
    elif cfg.n_heads:
        rules["act_q"] = P(dp, "model", None, None)      # sequence parallel
        rules["act_kv"] = P(dp, None, None, None)
    return rules


def input_specs(arch: str, shape: ShapeSpec, mesh, fsdp_pure: bool = False):
    """ShapeDtypeStructs + NamedShardings for one cell (no allocation)."""
    entry = registry.get(arch)
    cfg = entry.full
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def sds(shp, dt=i32):
        return jax.ShapeDtypeStruct(shp, dt)

    def batch_sharding(tree):
        return jax.tree_util.tree_map(
            lambda x: jax.NamedSharding(
                mesh, sh.batch_pspec(x.shape, mesh,
                                     include_model=fsdp_pure)),
            tree)

    fe_shapes = entry.frontend_shape(cfg, b)
    frontend = None if fe_shapes is None else {
        k: sds(v, jnp.bfloat16) for k, v in fe_shapes.items()}

    if shape.mode == "train":
        batch = {"tokens": sds((b, s)), "labels": sds((b, s))}
        if frontend is not None:
            batch["frontend"] = frontend
        return batch, batch_sharding(batch)
    if shape.mode == "prefill":
        batch = {"tokens": sds((b, s))}
        if frontend is not None:
            batch["frontend"] = frontend
        return batch, batch_sharding(batch)
    # decode: one new token against a cache of seq_len
    tokens = {"tokens": sds((b, 1))}
    return tokens, batch_sharding(tokens)


def tuned_cfg(cfg, level: int = 1):
    """§Perf 'tuned' model-config overrides (baseline = registry config)."""

    over = dict(causal_skip=True,
                lru_scan_chunk=4096 if cfg.lru_width else None)
    if level >= 2:
        # fewer, fatter attention chunks: kv re-reads scale with n_q_chunks
        over.update(q_chunk=1024, kv_chunk=2048)
    return dc.replace(cfg, **over)


def build_cell(arch: str, shape: ShapeSpec, mesh, variant: str = "baseline"):
    """Returns (fn, example_args, in_shardings, out_shardings, donate)."""

    entry = registry.get(arch)
    level = {"baseline": 0, "tuned": 1, "tuned2": 2}[variant]
    cfg = entry.full if level == 0 else tuned_cfg(entry.full, level)
    b, s = shape.global_batch, shape.seq_len
    tuned = level >= 1
    # fsdp_pure: frozen-backbone training of non-MoE archs (EP needs TP)
    fsdp_pure = tuned and shape.mode == "train" and cfg.n_experts == 0
    pspec = functools.partial(sh.param_pspec, fsdp_pure=fsdp_pure,
                              lru_gates_colparallel=tuned)

    if shape.mode == "train":
        tcfg = duplex_tcfg(cfg, backbone_dtype=(
            jnp.float8_e4m3fn if level >= 2 else jnp.bfloat16))
        state_shapes = jax.eval_shape(
            lambda k: ts.init_state(k, entry, cfg, tcfg, POLICY),
            jax.random.PRNGKey(0))
        state_specs = sh.to_named(
            sh.state_pspecs(state_shapes, mesh, pspec=pspec), mesh)
        batch, batch_specs = input_specs(arch, shape, mesh, fsdp_pure)
        fn = ts.make_train_step(entry, cfg, tcfg, POLICY)
        # out_shardings left to the compiler (donation keeps state in place)
        return (fn, (state_shapes, batch), (state_specs, batch_specs),
                None, (0,), cfg, fsdp_pure)

    params_shapes = jax.eval_shape(
        lambda k: entry.module.init_params(k, cfg), jax.random.PRNGKey(0))
    param_specs = sh.to_named(sh.tree_pspecs(params_shapes, mesh, pspec), mesh)

    if shape.mode == "prefill":
        batch, batch_specs = input_specs(arch, shape, mesh)
        step = ss.make_prefill_step(entry, cfg, max_len=s + 64, policy=POLICY,
                                    logits_mode="last" if tuned else "all")

        def fn(params, batch):
            return step(params, batch["tokens"], batch.get("frontend"))

        return (fn, (params_shapes, batch), (param_specs, batch_specs),
                None, (), cfg, False)

    # decode
    cache_shapes = jax.eval_shape(
        lambda: entry.module.init_cache(cfg, batch=b, max_len=s,
                                        dtype=jnp.bfloat16))
    cache_specs = sh.to_named(
        sh.tree_pspecs(cache_shapes, mesh, sh.cache_pspec), mesh)
    tokens, tok_specs = input_specs(arch, shape, mesh)
    step = ss.make_decode_step(entry, cfg, policy=POLICY)

    def fn(params, cache, tokens):
        return step(params, cache, tokens["tokens"])

    return (fn, (params_shapes, cache_shapes, tokens),
            (param_specs, cache_specs, tok_specs), None, (1,), cfg, False)


