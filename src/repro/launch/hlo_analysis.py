"""Post-compile HLO analysis for §Roofline.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE (verified in
tests), so for scan-over-layers models every per-layer cost is understated by
the trip count, and it reports no collective traffic at all.  This module
re-derives the three roofline inputs from the optimized HLO text with
*composed trip-count weighting* (nested scans multiply):

* ``dot_flops``        — 2·M·N·K per dot/convolution, trip-weighted;
* ``traffic_bytes``    — Σ (operand + result bytes) over scheduled
                         instructions (fusions internalize elementwise
                         chains), an HBM-traffic estimate;
* ``collective_bytes`` — Σ operand bytes per collective kind.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# instruction definition: "%name = <result shape(s)> opcode(operands), attrs"
# tuple results may contain "/*index=5*/" comments but never nested parens.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.-]+)\s*=\s*"
    r"(\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"([\w-]+)\(([^)]*)\)(.*)$")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.-]+)")
_TRIPCOUNT_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_WHILE_BODY_RE = re.compile(r"body=%?([\w.-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?\{?([\w.-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_BRANCH_RE = re.compile(r"(?:true|false)_computation=%?([\w.-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_NO_TRAFFIC_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "after-all", "partition-id", "replica-id", "iota",
}


@dataclasses.dataclass
class Instr:
    comp: str
    name: str
    result: str       # result shape text
    opcode: str
    operands: list
    attrs: str


def _shape_dims(shape_txt: str):
    m = _SHAPE_RE.search(shape_txt)
    if not m:
        return None, ()
    dtype, dims = m.groups()
    return dtype, tuple(int(d) for d in dims.split(",")) if dims else ()


def _shape_bytes(shape_txt: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_txt):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _comp_header(line: str):
    s = line.strip()
    if not s or not s.endswith("{") or "=" in s.split("(")[0]:
        return None, False
    m = re.match(r"^(ENTRY\s+)?%?([\w.-]+)\s+\(", s)
    if m:
        return m.group(2), bool(m.group(1))
    return None, False


class HloModule:
    """One-pass parse of scheduled HLO + composed trip multipliers."""

    def __init__(self, hlo_text: str):
        self.instrs: list[Instr] = []
        self.shapes: dict[str, str] = {}
        self.entry = None
        current = None
        for line in hlo_text.splitlines():
            header, is_entry = _comp_header(line)
            if header is not None:
                current = header
                if is_entry:
                    self.entry = header
                continue
            m = _INSTR_RE.match(line)
            if not m or current is None:
                continue
            name, result, opcode, operands, attrs = m.groups()
            self.shapes[name] = result
            self.instrs.append(Instr(current, name, result, opcode,
                                     _OPERAND_RE.findall(operands), attrs))
        self.mult = self._multipliers()

    def _multipliers(self) -> dict:
        edges = []
        for ins in self.instrs:
            if ins.opcode == "while":
                tm = _TRIPCOUNT_RE.search(ins.attrs)
                trip = int(tm.group(1)) if tm else 1
                bm = _WHILE_BODY_RE.search(ins.attrs)
                cm = _WHILE_COND_RE.search(ins.attrs)
                if bm:
                    edges.append((ins.comp, bm.group(1), float(trip)))
                if cm:
                    edges.append((ins.comp, cm.group(1), float(trip)))
            elif ins.opcode == "conditional":
                # data-dependent branches: weight each by 1/n (expected value
                # under a uniform predicate — exact for index-driven guards
                # like the causal-skip schedule whose hit rate is ~1/2)
                branches = []
                bm = _BRANCH_RE.search(ins.attrs)
                if bm:
                    branches = _OPERAND_RE.findall(bm.group(1)) or \
                        re.findall(r"[\w.-]+", bm.group(1))
                branches += _TF_BRANCH_RE.findall(ins.attrs)
                for b in branches:
                    edges.append((ins.comp, b, 1.0 / max(len(branches), 1)))
            else:
                for m in _CALL_RE.finditer(ins.attrs):
                    edges.append((ins.comp, m.group(1), 1.0))
        mult = {self.entry: 1.0} if self.entry else {}
        for _ in range(64):
            changed = False
            for parent, child, trip in edges:
                if parent in mult:
                    new = mult[parent] * trip
                    if mult.get(child, 0) < new:
                        mult[child] = new
                        changed = True
            if not changed:
                break
        return mult

    # ------------------------------------------------------------------
    def dot_flops(self) -> float:
        """Trip-weighted 2·M·N·K over all dot ops (+conv as dots)."""
        total = 0.0
        for ins in self.instrs:
            if ins.opcode not in ("dot", "convolution"):
                continue
            _, rdims = _shape_dims(ins.result)
            out_elems = 1
            for d in rdims:
                out_elems *= d
            k = 1
            cm = _CONTRACT_RE.search(ins.attrs)
            if cm and ins.operands:
                lhs_shape = self.shapes.get(ins.operands[0], "")
                _, ldims = _shape_dims(lhs_shape)
                for ci in cm.group(1).split(","):
                    if ci and int(ci) < len(ldims):
                        k *= ldims[int(ci)]
            total += 2.0 * out_elems * k * self.mult.get(ins.comp, 1)
        return total

    # ops whose operands/results genuinely stream HBM on TPU; elementwise
    # chains fuse into their consumers and live in VMEM/registers.
    _HBM_OPS = frozenset({
        "dot", "convolution", "copy", "transpose", "dynamic-update-slice",
        "dynamic-slice", "gather", "scatter", "reduce", "sort",
    })

    def traffic_bytes(self, fusion_aware: bool = True) -> float:
        """Trip-weighted HBM-traffic estimate (bytes, per device).

        ``fusion_aware=True`` (the roofline's memory term): counts
        operand+result bytes only for ops that stream HBM on TPU — matmuls,
        materializing copies/transposes, cache updates, gathers/reductions.
        ``False``: every scheduled instruction (pessimistic upper bound —
        the CPU backend's fusion granularity, reported for reference).
        """
        total = 0.0
        for ins in self.instrs:
            if ins.opcode in _NO_TRAFFIC_OPS or ins.opcode == "while":
                continue
            if fusion_aware and ins.opcode not in self._HBM_OPS:
                continue
            if ins.opcode == "dynamic-slice":
                # reads only the sliced window (+writes it): 2× result
                nbytes = 2 * _shape_bytes(ins.result)
            elif ins.opcode == "dynamic-update-slice":
                # reads the update and writes that region in place: 2× update
                upd = self.shapes.get(ins.operands[1], "") \
                    if len(ins.operands) > 1 else ""
                nbytes = 2 * _shape_bytes(upd)
            else:
                nbytes = _shape_bytes(ins.result)
                for op in ins.operands:
                    nbytes += _shape_bytes(self.shapes.get(op, ""))
            total += nbytes * self.mult.get(ins.comp, 1)
        return total

    def collective_bytes(self) -> dict:
        """Per-device wire-traffic estimate per collective kind.

        all-gather is counted at RESULT size (a ring gather delivers the
        full array to every device; its operand is just the local shard —
        operand-summing would undercount by the gather factor).  all-reduce
        at operand size ≈ one full pass (ring AR moves 2·(N−1)/N ≈ 2× this;
        the single-pass convention is kept consistently across kinds).
        reduce-scatter / all-to-all / collective-permute at operand size.
        """
        out: dict = defaultdict(int)
        counts: dict = defaultdict(int)
        for ins in self.instrs:
            kind = ins.opcode.removesuffix("-start")
            if kind not in COLLECTIVE_KINDS or ins.opcode.endswith("-done"):
                continue
            if kind == "all-gather":
                nbytes = _shape_bytes(ins.result)
            else:
                nbytes = sum(_shape_bytes(self.shapes.get(op, ""))
                             for op in ins.operands)
            m = self.mult.get(ins.comp, 1)
            out[kind] += nbytes * m
            counts[kind] += m
        out["total"] = sum(out[k] for k in COLLECTIVE_KINDS if k in out)
        out["counts"] = dict(counts)
        return dict(out)

    def op_census(self) -> dict:
        census: dict = defaultdict(int)
        for ins in self.instrs:
            census[ins.opcode] += self.mult.get(ins.comp, 1)
        return dict(census)


def collective_bytes(hlo_text: str) -> dict:
    return HloModule(hlo_text).collective_bytes()


def count_ops(hlo_text: str, names: tuple[str, ...]) -> dict:
    census = HloModule(hlo_text).op_census()
    return {n: census.get(n, 0) for n in names}


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    return {
        "dot_flops": mod.dot_flops(),
        "traffic_bytes": mod.traffic_bytes(),
        "collectives": mod.collective_bytes(),
        "census_top": dict(sorted(mod.op_census().items(),
                                  key=lambda kv: -kv[1])[:12]),
    }
