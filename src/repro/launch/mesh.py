"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: 16×16 = 256 chips (v5e pod slice); multi-pod
adds a leading ``pod`` axis (2×16×16 = 512 chips) — the pod axis carries
data-parallel gradient reduction only (weights are replicated across pods,
FSDP-sharded within a pod; DESIGN.md §6).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the locally available devices (tests / examples)."""
    n = len(jax.devices())
    data = max(n // model, 1)
    return jax.make_mesh((data, model), ("data", "model"))
