"""Production serving launcher: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b \
        --preset smoke --batch 4 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.distributed import ctx, sharding as sh
from repro.launch.cells import activation_rules
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import layers as L, registry
from repro.train import serve_step as ss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(registry.ARCHS))
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "pod", "multipod"])
    args = ap.parse_args()

    entry = registry.get(args.arch)
    cfg = entry.config(args.preset)
    policy = L.Policy(compute_dtype=(jnp.bfloat16 if args.preset == "full"
                                     else jnp.float32))
    cache_dtype = jnp.bfloat16 if args.preset == "full" else jnp.float32
    mesh = make_host_mesh() if args.mesh == "host" else \
        make_production_mesh(multi_pod=args.mesh == "multipod")
    max_len = args.prompt_len + args.gen + 8

    with mesh, ctx.activation_sharding(mesh, activation_rules(cfg, mesh)):
        params = entry.module.init_params(jax.random.PRNGKey(0), cfg)
        param_specs = sh.to_named(
            sh.tree_pspecs(params, mesh, sh.param_pspec), mesh)
        params = jax.device_put(params, param_specs)

        fe = entry.frontend_shape(cfg, args.batch)
        frontend = None if fe is None else {
            k: jax.random.normal(jax.random.PRNGKey(7), v).astype(
                policy.compute_dtype) * 0.1 for k, v in fe.items()}

        prefill = ss.make_prefill_step(entry, cfg, max_len=max_len,
                                       policy=policy,
                                       cache_dtype=cache_dtype,
                                       logits_mode="last")
        decode = jax.jit(ss.make_decode_step(entry, cfg, policy=policy),
                         donate_argnums=1)

        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            cfg.vocab)
        t0 = time.time()
        out = prefill(params, prompts, frontend) if frontend else \
            prefill(params, prompts)
        cache = out["cache"]
        tok = jnp.argmax(out["next_token_logits"], -1)[:, None] \
            .astype(jnp.int32)
        jax.block_until_ready(tok)
        print(f"prefill: {time.time()-t0:.2f}s")
        t0 = time.time()
        toks = [tok]
        for _ in range(args.gen - 1):
            tok, cache = decode(params, cache, tok)
            toks.append(tok)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        print(f"decode: {args.gen-1} steps, "
              f"{(args.gen-1)*args.batch/dt:.1f} tok/s")
        gen = jnp.concatenate(toks, axis=1)
        print("first sequence:", [int(t) for t in gen[0]])


if __name__ == "__main__":
    main()
