"""repro.launch"""
