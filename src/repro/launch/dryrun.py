import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the real step function (duplex train_step /
prefill_step / decode_step), the ShapeDtypeStruct input specs, and the
NamedShardings from ``distributed.sharding``; lowers, compiles, and records
``memory_analysis()`` / ``cost_analysis()`` / HLO collective traffic to a
JSON file that §Dry-run / §Roofline read.

One cell per process (jax locks the device count at first init; fresh
processes also keep compile memory bounded):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k --mesh pod --out experiments/dryrun
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.configs.common import SHAPES, ShapeSpec
from repro.core import duplex as dx
from repro.distributed import ctx, sharding as sh
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import layers as L, registry
from repro.optim import SGDConfig
from repro.train import serve_step as ss, train_step as ts

from repro.launch.cells import (POLICY, activation_rules, build_cell,
                                duplex_tcfg, input_specs, tuned_cfg)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             save_hlo: bool = False, variant: str = "baseline") -> dict:
    shape = SHAPES[shape_name]
    entry = registry.get(arch)
    mesh_name = "multipod" if multi_pod else "pod"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "mode": shape.mode, "variant": variant}

    if shape.name == "long_500k" and not entry.full.supports_long_context:
        rec["status"] = "skipped"
        rec["reason"] = "quadratic attention cannot serve 500k context"
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, in_sh, out_sh, donate, cfg, fsdp_pure = build_cell(
        arch, shape, mesh, variant)

    with mesh, ctx.activation_sharding(
            mesh, activation_rules(cfg, mesh, fsdp_pure=fsdp_pure)):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    mod = hlo_analysis.HloModule(hlo)
    coll = mod.collective_bytes()

    def _mem(field):
        return int(getattr(mem, field, -1)) if mem is not None else -1

    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "memory": {
            "argument_bytes": _mem("argument_size_in_bytes"),
            "output_bytes": _mem("output_size_in_bytes"),
            "temp_bytes": _mem("temp_size_in_bytes"),
            "generated_code_bytes": _mem("generated_code_size_in_bytes"),
        },
        "cost": {
            # raw XLA numbers (while bodies counted once — see hlo_analysis)
            "xla_flops": float(cost.get("flops", -1)),
            "xla_bytes_accessed": float(cost.get("bytes accessed", -1)),
            # trip-weighted re-derivations (per device)
            "dot_flops": mod.dot_flops(),
            "traffic_bytes": mod.traffic_bytes(fusion_aware=True),
            "traffic_bytes_pessimistic": mod.traffic_bytes(fusion_aware=False),
        },
        "collectives": coll,
        "hlo_ops": {k: mod.op_census().get(k, 0)
                    for k in ("fusion", "dot", "while", "custom-call")},
    })
    if save_hlo:
        suffix = "" if variant == "baseline" else f"__{variant}"
        (out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.hlo.txt"
         ).write_text(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(registry.ARCHS))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "tuned", "tuned2"])
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{args.arch}__{args.shape}__{args.mesh}"
    if args.variant != "baseline":
        name += f"__{args.variant}"
    try:
        rec = run_cell(args.arch, args.shape, args.mesh == "multipod",
                       out_dir, save_hlo=args.save_hlo,
                       variant=args.variant)
    except Exception as e:  # recorded, not swallowed — sweep reports it
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    (out_dir / f"{name}.json").write_text(json.dumps(rec, indent=2))
    status = rec["status"]
    extra = rec.get("reason") or rec.get("error", "")
    print(f"[dryrun] {name}: {status} {extra}")
    if status == "ok":
        m, c = rec["memory"], rec["cost"]
        print(f"  args={m['argument_bytes']/2**30:.2f}GiB "
              f"temp={m['temp_bytes']/2**30:.2f}GiB "
              f"dot_flops={c['dot_flops']:.3e} "
              f"coll={rec['collectives'].get('total', 0)/2**30:.2f}GiB "
              f"compile={rec['compile_s']:.0f}s")
    raise SystemExit(0 if status in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
