"""Production training launcher.

On a real TPU slice this process runs once per host (``jax.distributed``
initializes from the cluster env); the same entry point runs on CPU for
local smoke runs with ``--preset smoke``.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b \
        --preset smoke --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointConfig
from repro.data.pipeline import DataConfig
from repro.distributed import ctx, sharding as sh
from repro.launch.cells import activation_rules, duplex_tcfg
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import layers as L, registry
from repro.train import loop, train_step as ts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(registry.ARCHS))
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--mode", default="duplex", choices=["duplex", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8, help="global batch")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "pod", "multipod"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--distributed", action="store_true",
                    help="initialize jax.distributed from cluster env")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    entry = registry.get(args.arch)
    cfg = entry.config(args.preset)
    policy = L.Policy(compute_dtype=(jnp.bfloat16 if args.preset == "full"
                                     else jnp.float32))
    tcfg = duplex_tcfg(cfg) if args.mode == "duplex" else \
        ts.TrainConfig(mode="full")
    if args.preset == "smoke":
        import dataclasses as dc
        from repro.core import duplex as dx
        tcfg = dc.replace(
            tcfg, backbone_dtype=jnp.float32,
            duplex=dx.DuplexConfig(n_blocks=2, d_branch=32, pool_factor=4,
                                   branch_heads=2,
                                   bfp=L.BFPPolicy(enabled=True,
                                                   group=(3, 3))))

    mesh = make_host_mesh() if args.mesh == "host" else \
        make_production_mesh(multi_pod=args.mesh == "multipod")

    with mesh, ctx.activation_sharding(mesh, activation_rules(cfg, mesh)):
        state_specs = sh.to_named(
            sh.state_pspecs(
                jax.eval_shape(lambda k: ts.init_state(k, entry, cfg, tcfg,
                                                       policy),
                               jax.random.PRNGKey(0)), mesh), mesh)
        step = jax.jit(ts.make_train_step(entry, cfg, tcfg, policy),
                       donate_argnums=0)

        def init_fn():
            st = ts.init_state(jax.random.PRNGKey(0), entry, cfg, tcfg,
                               policy)
            return jax.device_put(st, state_specs)

        def step_fn(state, batch):
            return step(state, {k: jnp.asarray(v) for k, v in batch.items()})

        report = loop.run(
            loop.LoopConfig(
                total_steps=args.steps, ckpt_every=args.ckpt_every,
                ckpt=(CheckpointConfig(args.ckpt_dir)
                      if args.ckpt_dir else None),
                log_every=10, step_deadline_s=60.0),
            DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                       batch_per_host=args.batch,
                       seed=jax.process_index()),
            step_fn, init_fn)
    print(f"finished {report.steps_run} steps in {report.wall_s:.1f}s")


if __name__ == "__main__":
    main()
