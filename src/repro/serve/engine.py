"""Decode-trace generator: traffic → one interleaved Op/TraceEvent stream.

``lower_traffic`` walks a request stream through a continuous-batching
slot scheduler and emits the same artifacts the training schedule
builders produce — an ordered op schedule ``[(name, start_s, end_s)]``
and a per-tensor :class:`~repro.core.schedule.TraceEvent` stream — so
the existing ``repro.memory`` controller and ``repro.sim.timeline``
engine replay serving workloads *unchanged*.

Cache entries are per-token-position KV tensors (``kv<rid>.<pos>``, all
layers folded — see :class:`~repro.serve.model.ServeModel`).  An entry
is written at its op's end and re-read at the start of **every**
subsequent decode step of its session — the token-position-dependent
lifetime that makes serving the opposite of CAMEL's training transients:
entries live until session end, far past the eDRAM retention floor.

The KV policy is applied inline, because recompute changes op *work* and
therefore op *time* — a post-hoc trace transform could not keep the
schedule self-consistent:

``always`` / ``skip``
    No trace transform; the refresh machinery decides everything
    (``always`` refreshes every bank; ``skip`` = ``selective`` +
    ``reads_restore`` — a read restores the row, so a bank whose
    entries are all re-read within retention never pulses).
``evict``
    An entry whose next read falls past its retention deadline is
    dropped **at the deadline** (an ``evict`` event, timestamped in the
    past relative to the current op — the event list is re-sorted at
    the end); the session keeps decoding with a shorter context
    (``reads_dropped`` is the accuracy proxy).
``recompute``
    Same deadline eviction, but the decode op re-derives the entry from
    the layer input — ``recompute_macs_per_entry`` added to the op's
    work (so recompute time scales 1/f and its energy ∝ V² through the
    cost model) and a fresh ``write`` at the op's start; the entry is
    not read that step (the recomputed value feeds attention directly).

Slot-scheduler diagnostics (request admitted / preempted / session
cache released) go through ``repro.obs.log`` at DEBUG — enable with
``REPRO_LOG=debug``; stdout stays untouched.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Callable, List, Optional, Sequence

from repro.core.schedule import TraceEvent
from repro.obs import log as obslog
from repro.serve.model import ServeModel
from repro.serve.traffic import Request, TrafficSpec
from repro.serve.traffic import requests as traffic_requests

KV_POLICIES = ("always", "skip", "evict", "recompute")


@dataclasses.dataclass
class ServeStats:
    """What the engine did, summed over the whole trace (the serving
    dict on ``ArmReport`` is built from this)."""
    tokens_served: int = 0         # decode ops executed
    prefill_tokens: int = 0
    requests_completed: int = 0
    requests_preempted: int = 0
    kv_entries_evicted: int = 0    # deadline drops (evict + recompute)
    kv_entries_recomputed: int = 0
    reads_dropped: int = 0         # cache reads lost to evictions
    total_macs: float = 0.0        # incl. prefill + recompute work
    read_bits: float = 0.0
    write_bits: float = 0.0
    peak_live_bits: float = 0.0
    max_lifetime_s: float = 0.0    # longest entry write→release window
    latencies_s: List[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeTrace:
    """One lowered traffic run: the schedule/trace pair the sim pipeline
    consumes, plus the engine's statistics."""
    events: List[TraceEvent]
    op_schedule: List[tuple]       # [(op name, start_s, end_s), ...]
    duration_s: float
    stats: ServeStats


class _Session:
    __slots__ = ("req", "slot", "tokens_done", "cache", "lost")

    def __init__(self, req: Request, slot: int):
        self.req = req
        self.slot = slot
        self.tokens_done = 0
        self.cache: dict = {}      # position -> last touch time (s)
        self.lost = 0              # positions evicted (evict policy)


def lower_traffic(model: ServeModel, spec: TrafficSpec,
                  reqs: Optional[Sequence[Request]] = None, *,
                  op_seconds: Callable[[float], float],
                  bits_per_value: float,
                  kv_policy: str = "always",
                  retention_s: float = math.inf) -> ServeTrace:
    """Lower a request stream into one interleaved serving trace.

    Args:
        model: per-token work / KV shape (:class:`ServeModel`).
        spec: traffic spec; ``reqs`` overrides its generated stream
            (must be in arrival order).
        op_seconds: MACs → seconds at the arm's operating point (the
            cost stage's pricing; serving ops are MAC-streamed, port
            timing is resolved per bank by the memory replay).
        bits_per_value: storage bits per KV value (BFP on eDRAM).
        kv_policy: one of :data:`KV_POLICIES` (see module docstring).
        retention_s: the wall-clock retention floor the ``evict`` /
            ``recompute`` policies expire entries against (ignored by
            ``always``/``skip`` — their refresh behaviour lives in the
            memory controller).

    Returns:
        A :class:`ServeTrace`; ``events`` are globally time-sorted
        (stable, so intra-op emission order breaks ties).
    """
    if kv_policy not in KV_POLICIES:
        raise ValueError(f"unknown kv policy {kv_policy!r}; "
                         f"choose from {KV_POLICIES}")
    upcoming = collections.deque(traffic_requests(spec) if reqs is None
                                 else reqs)
    expiring = kv_policy in ("evict", "recompute")
    entry_bits = model.kv_entry_bits(bits_per_value)
    stats = ServeStats()
    events: List[TraceEvent] = []
    sched: List[tuple] = []
    pending: collections.deque = collections.deque()
    slots: dict = {}                       # slot index -> _Session
    free_slots = list(range(spec.max_batch - 1, -1, -1))   # pop() = lowest
    births: dict = {}                      # tensor -> write time
    live_entries = peak_live = 0
    t = 0.0

    def _release(tensor: str, when: float) -> None:
        b = births.pop(tensor, None)
        if b is not None:
            stats.max_lifetime_s = max(stats.max_lifetime_s, when - b)

    def _drop_session(sess: _Session, op: str, when: float,
                      kind: str) -> None:
        nonlocal live_entries
        for pos in sorted(sess.cache):
            name = f"kv{sess.req.rid}.{pos}"
            events.append(TraceEvent(time=when, op=op, tensor=name,
                                     kind=kind, bits=entry_bits))
            _release(name, when)
        live_entries -= len(sess.cache)
        del slots[sess.slot]
        free_slots.append(sess.slot)
        free_slots.sort(reverse=True)

    while upcoming or pending or slots:
        # absorb every request that has arrived by now
        while upcoming and upcoming[0].arrival_s <= t:
            pending.append(upcoming.popleft())
        if not slots and not pending:
            t = max(t, upcoming[0].arrival_s)    # idle: jump to arrival
            continue

        # session churn: a full batch preempts its longest-running
        # session (past the preempt_after floor) to admit a queued one
        if spec.preempt_after is not None and pending and not free_slots:
            victims = [s for s in slots.values()
                       if s.tokens_done >= spec.preempt_after]
            if victims:
                v = max(victims, key=lambda s: (s.tokens_done, -s.req.rid))
                _drop_session(v, f"x{v.req.rid}", t, "evict")
                stats.requests_preempted += 1
                obslog.debug("request_preempted", rid=v.req.rid,
                             slot=v.slot, tokens_done=v.tokens_done,
                             t_us=t * 1e6)

        # admit into free slots; prefills serialize on the one array
        while pending and free_slots:
            req = pending.popleft()
            slot = free_slots.pop()
            op = f"p{req.rid}"
            macs = model.prefill_macs(req.prompt_len)
            t1 = t + op_seconds(macs)
            sess = _Session(req, slot)
            for pos in range(req.prompt_len):
                name = f"kv{req.rid}.{pos}"
                events.append(TraceEvent(time=t1, op=op, tensor=name,
                                         kind="write", bits=entry_bits))
                births[name] = t1
                sess.cache[pos] = t1
            sched.append((op, t, t1))
            slots[slot] = sess
            stats.total_macs += macs
            stats.prefill_tokens += req.prompt_len
            stats.write_bits += entry_bits * req.prompt_len
            live_entries += req.prompt_len
            peak_live = max(peak_live, live_entries)
            obslog.debug("request_admitted", rid=req.rid, slot=slot,
                         prompt_len=req.prompt_len, gen_len=req.gen_len,
                         queued_us=(t - req.arrival_s) * 1e6)
            t = t1

        # one decode op per active session, round-robin in slot order
        for slot in sorted(slots):
            sess = slots[slot]
            req = sess.req
            op = f"d{req.rid}.{sess.tokens_done}"
            t0 = t
            n_reads = n_recomputed = 0
            for pos in sorted(sess.cache):
                name = f"kv{req.rid}.{pos}"
                last = sess.cache[pos]
                if expiring and t0 - last >= retention_s:
                    # expired: drop at the deadline, not at discovery
                    deadline = last + retention_s
                    events.append(TraceEvent(time=deadline, op=op,
                                             tensor=name, kind="evict",
                                             bits=entry_bits))
                    _release(name, deadline)
                    stats.kv_entries_evicted += 1
                    if kv_policy == "evict":
                        del sess.cache[pos]
                        sess.lost += 1
                        live_entries -= 1
                        continue
                    # recompute: re-derive and re-write at op start; the
                    # fresh value feeds attention directly (no read)
                    events.append(TraceEvent(time=t0, op=op, tensor=name,
                                             kind="write",
                                             bits=entry_bits))
                    births[name] = t0
                    sess.cache[pos] = t0
                    n_recomputed += 1
                    stats.kv_entries_recomputed += 1
                    stats.write_bits += entry_bits
                    continue
                events.append(TraceEvent(time=t0, op=op, tensor=name,
                                         kind="read", bits=entry_bits))
                sess.cache[pos] = t0
                n_reads += 1
            stats.reads_dropped += sess.lost
            stats.read_bits += entry_bits * n_reads
            # the new token attends to the surviving cache and itself
            macs = (model.proj_macs_per_token
                    + model.attn_macs(n_reads + n_recomputed + 1)
                    + model.recompute_macs_per_entry * n_recomputed)
            t1 = t0 + op_seconds(macs)
            new_pos = req.prompt_len + sess.tokens_done
            name = f"kv{req.rid}.{new_pos}"
            events.append(TraceEvent(time=t1, op=op, tensor=name,
                                     kind="write", bits=entry_bits))
            births[name] = t1
            sess.cache[new_pos] = t1
            sched.append((op, t0, t1))
            stats.total_macs += macs
            stats.tokens_served += 1
            stats.write_bits += entry_bits
            live_entries += 1
            peak_live = max(peak_live, live_entries)
            sess.tokens_done += 1
            t = t1
            if sess.tokens_done >= req.gen_len:
                n_cache = len(sess.cache)
                _drop_session(sess, op, t, "free")
                stats.requests_completed += 1
                stats.latencies_s.append(t - req.arrival_s)
                obslog.debug("session_evicted", rid=req.rid, slot=slot,
                             cache_entries=n_cache,
                             latency_us=(t - req.arrival_s) * 1e6)

    # deadline evictions are timestamped in the past relative to their
    # discovering op — restore global time order (stable: intra-op
    # emission order, e.g. write-then-free at equal times, is kept)
    events.sort(key=lambda ev: ev.time)
    stats.peak_live_bits = peak_live * entry_bits
    return ServeTrace(events=events, op_schedule=sched, duration_s=t,
                      stats=stats)
