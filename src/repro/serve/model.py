"""Serving-side model shape: the per-token work a decoder LM does.

The training sim lowers DuDNN blocks; serving lowers a small decoder
transformer instead — what matters to the memory system is not the
architecture zoo but the KV cache's shape and the MAC work per token,
so :class:`ServeModel` keeps exactly those knobs (Kelle, arXiv
2510.16040, models edge LLM decoding the same way: projections +
attention over a cache whose entries are long-lived relative to eDRAM
retention).

Units: MACs are multiply-accumulates on the systolic array (priced into
seconds by the arm's cost model); KV sizes are **values** (one K or V
element), converted to bits by the pipeline's bits-per-value (BFP on
eDRAM).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ServeModel:
    """A decoder LM as the memory system sees it.

    ``d_kv`` is the **values per KV entry per layer** for one token
    position — key and value concatenated (2 × the per-layer head
    width).  One *cache entry* in the trace is one token position's KV
    across **all** layers (``d_kv × n_layers`` values): per-layer
    splitting would multiply the event count by ``n_layers`` without
    changing any lifetime — every layer's slice of position *t* is
    written by the same op and re-read by every subsequent decode step.
    """
    n_layers: int = 8
    d_model: int = 32
    mlp_ratio: int = 4             # MLP hidden / d_model
    d_kv: int = 64                 # K+V values per entry per layer

    @property
    def proj_macs_per_token(self) -> float:
        """Cache-independent MACs per decoded token: the QKV/output
        projections (4·d²) plus the MLP (2·ratio·d²), per layer."""
        return float((4 + 2 * self.mlp_ratio)
                     * self.d_model ** 2 * self.n_layers)

    def attn_macs(self, entries: int) -> float:
        """Attention MACs over ``entries`` live cache entries (QK^T plus
        the value mix: 2 MACs per cached value, all layers)."""
        return 2.0 * self.d_kv * self.n_layers * entries

    def prefill_macs(self, prompt_len: int) -> float:
        """One prefill op's MACs: per-token projections plus causal
        attention over the growing prefix (Σ 2·d_kv·L·i ≈ d_kv·L·P²)."""
        return (prompt_len * self.proj_macs_per_token
                + self.d_kv * self.n_layers * float(prompt_len) ** 2)

    @property
    def recompute_macs_per_entry(self) -> float:
        """MACs to re-derive one expired cache entry from the layer
        input (the KV projections for one position, all layers) — what
        the ``recompute`` KV policy adds to the decode op instead of
        reading the entry back."""
        return 2.0 * self.d_model * self.d_kv * self.n_layers

    def kv_entry_bits(self, bits_per_value: float) -> float:
        """Storage footprint of one cache entry (all layers) in bits."""
        return self.d_kv * self.n_layers * bits_per_value
