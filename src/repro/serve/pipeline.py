"""The serving arm family and its pipeline stages.

A :class:`ServeArm` runs through the same five-stage pipeline shape as
the training arms — schedule → cost → trace → memory → energy — with
serving-specific schedule/cost/trace/energy stages and the **memory
stage reused verbatim** (``stage_timeline`` / ``stage_memory``), so the
whole bank/refresh/DVFS machinery, flight-recorder spans, and
``repro.obs.reconcile`` exact-equality work on serving traces out of
the box.  ``sim.run(arm, timing=...)`` picks the right pipeline via the
arm's :meth:`ServeArm.select_pipeline` hook.

The KV policy maps onto controller mechanisms:

=============  ==============  =============  =========================
policy         refresh_policy  reads_restore  engine trace transform
=============  ==============  =============  =========================
``always``     always          no             none
``skip``       selective       yes            none (reads restore rows;
                                              refresh only fires when a
                                              gap exceeds retention)
``evict``      none            yes            drop expired entries at
                                              their deadline
``recompute``  none            yes            drop + re-derive expired
                                              entries (extra MACs)
=============  ==============  =============  =========================

``evict``/``recompute`` never refresh — expiry is handled in the trace
itself, and the dropped data is the accounted cost (``evict`` events /
recompute work), which is why their reports show
``refresh_free=False``-style ``safe`` flags: data *was* dropped, by
design, before its last reader.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core import edram as ed
from repro.core import hwmodel as hw
from repro.core.schedule import OpWork
from repro.serve.engine import KV_POLICIES, lower_traffic
from repro.serve.model import ServeModel
from repro.serve.traffic import TrafficSpec
from repro.serve.traffic import requests as traffic_requests
from repro.sim.arm import Arm, WorkloadSpec, register_arm
from repro.sim.cost import resolve_cost
from repro.sim.pipeline import (Pipeline, SimContext, stage_memory)
from repro.sim.report import ArmReport
from repro.sim.timeline import stage_timeline

#: SystemConfig fields each KV policy implies (see module docstring)
POLICY_SYSTEM = {
    "always": dict(refresh_policy="always", reads_restore=False),
    "skip": dict(refresh_policy="selective", reads_restore=True),
    "evict": dict(refresh_policy="none", reads_restore=True),
    "recompute": dict(refresh_policy="none", reads_restore=True),
}


@dataclasses.dataclass(frozen=True)
class ServeArm(Arm):
    """One serving arm: model shape + traffic + KV policy + system.

    Subclasses :class:`~repro.sim.arm.Arm`, so the registry,
    ``with_system``/``with_cost``, and the ``sim.sweep`` grid axes
    (temps, freqs) all apply — ``dataclasses.replace`` preserves the
    subclass, so a swept serving arm stays a serving arm.  The training
    ``workload`` is absent (serving lowers traffic, not DuDNN blocks)
    and ``iters_to_target`` is ``None`` (no TTA/ETA projection —
    serving throughput lives in ``ArmReport.serving``).
    """
    reversible: bool = False
    workload: Optional[WorkloadSpec] = None
    iters_to_target: Optional[float] = None
    model: ServeModel = ServeModel()
    traffic: TrafficSpec = TrafficSpec()
    kv_policy: str = "always"

    def select_pipeline(self, timing: str) -> Pipeline:
        """The serving pipeline a ``timing`` name selects (the hook
        ``sim.run`` calls when no explicit pipeline is passed)."""
        if timing == "timeline":
            return SERVE_TIMELINE_PIPELINE
        if timing == "additive":
            return SERVE_ADDITIVE_PIPELINE
        raise ValueError(f"unknown timing {timing!r} for serving arm "
                         f"{self.name!r}; choose from "
                         f"('additive', 'timeline')")

    def with_policy(self, policy: str) -> "ServeArm":
        """The same arm under a different KV policy (system refresh
        fields re-derived; the name's policy suffix follows)."""
        base = self.name.rsplit("/", 1)[0] if "/" in self.name else "Serve"
        return serve_arm(policy, name=f"{base}/{policy}",
                         model=self.model, traffic=self.traffic,
                         system=self.system, cost=self.cost)

    def with_traffic(self, **fields) -> "ServeArm":
        """New arm with :class:`TrafficSpec` fields replaced."""
        return dataclasses.replace(
            self, traffic=dataclasses.replace(self.traffic, **fields))

    def with_model(self, **fields) -> "ServeArm":
        """New arm with :class:`ServeModel` fields replaced."""
        return dataclasses.replace(
            self, model=dataclasses.replace(self.model, **fields))


def serve_arm(policy: str = "always", *, name: Optional[str] = None,
              model: ServeModel = ServeModel(),
              traffic: TrafficSpec = TrafficSpec(),
              system: Optional[hw.SystemConfig] = None,
              cost=None) -> ServeArm:
    """Build a serving arm: the KV ``policy`` sets the system's
    ``refresh_policy``/``reads_restore`` fields (see module table); any
    explicit ``system`` is re-derived onto the policy's mechanism."""
    if policy not in KV_POLICIES:
        raise ValueError(f"unknown kv policy {policy!r}; "
                         f"choose from {KV_POLICIES}")
    name = name or f"Serve/{policy}"
    base = system if system is not None else hw.SystemConfig(name=name)
    return ServeArm(name=name,
                    system=dataclasses.replace(base,
                                               **POLICY_SYSTEM[policy]),
                    model=model, traffic=traffic, kv_policy=policy,
                    cost=cost)


# ------------------------------------------------------------------ stages

def stage_serve_schedule(arm: ServeArm, ctx: SimContext) -> None:
    """Resolve the traffic: the concrete seeded request stream."""
    cfg = arm.system
    ctx.bits = hw.BFP_BITS if cfg.use_edram else hw.FP16_BITS
    ctx.batch = 1.0      # KV entries are full tensors, never per-sample
    ctx.extra["requests"] = traffic_requests(arm.traffic)


def stage_serve_cost(arm: ServeArm, ctx: SimContext) -> None:
    """Resolve the operating point.  The decode GEMVs are batched and
    weight-stationary-pipelined, so the array runs at its peak MAC rate
    (``array² × f``) — serving utilization losses show up as port
    stalls in the memory replay, not as a derated MAC rate."""
    cfg = arm.system
    point = resolve_cost(arm.cost, cfg)
    ctx.cost = point
    ctx.freq_hz = point.freq_hz
    ctx.compute_scale = point.compute_scale
    ctx.R = float(cfg.array ** 2) * point.freq_hz


def stage_serve_trace(arm: ServeArm, ctx: SimContext) -> None:
    """Run the decode-trace generator (``repro.serve.engine``) at the
    resolved operating point; its op schedule / event stream feed the
    unchanged memory stage."""
    cfg = arm.system
    point, R = ctx.cost, ctx.R

    def op_seconds(macs: float) -> float:
        return point.op_seconds(OpWork(macs=macs), R)

    retention = ed.retention_s(cfg.temp_c) if cfg.use_edram else math.inf
    tr = lower_traffic(arm.model, arm.traffic, ctx.extra["requests"],
                       op_seconds=op_seconds, bits_per_value=ctx.bits,
                       kv_policy=arm.kv_policy, retention_s=retention)
    ctx.events = tr.events
    ctx.op_schedule = tr.op_schedule
    ctx.op_durations = {op: end - start
                        for op, start, end in tr.op_schedule}
    ctx.duration_s = tr.duration_s
    ctx.read_bits = tr.stats.read_bits
    ctx.write_bits = tr.stats.write_bits
    ctx.peak_live_bits = tr.stats.peak_live_bits
    ctx.max_lifetime_s = tr.stats.max_lifetime_s
    ctx.extra["serve"] = tr.stats


def _percentile(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1,
            max(0, math.ceil(q * len(sorted_vals)) - 1))
    return sorted_vals[i]


def stage_serve_energy(arm: ServeArm, ctx: SimContext) -> None:
    """Serving energy/latency accounting; assembles the ArmReport.

    There is no closed-form scalar oracle for a traffic-interleaved
    trace (the training oracle assumes one iteration's streamed working
    set), so ``scalar_memory_j``/``oracle_rel_err`` report 0.0 — the
    controller replay *is* the model here.  Serving throughput numbers
    land in ``report.serving``.
    """
    cfg = arm.system
    stats = ctx.extra["serve"]
    compute_j = stats.total_macs * (cfg.mac_pj if cfg.use_edram
                                    else cfg.mac_pj_fp16) * 1e-12 \
        * ctx.compute_scale
    ctrl = ctx.controller
    if ctrl is not None:
        memory_j = ctrl.energy.total_j
        stall_s = ctrl.stall_s
        offchip_bits = ctrl.offchip_bits
        rf = ((not any(b.refreshed for b in ctrl.banks)) and ctrl.safe
              if cfg.use_edram else True)
    else:
        memory_j = 0.0
        stall_s = 0.0
        offchip_bits = 0.0
        rf = False
    latency_s = ctx.duration_s + stall_s + (
        offchip_bits / cfg.offchip_bw_bps if offchip_bits else 0.0)
    leakage_j = 0.0
    if cfg.charge_leakage:
        mw_per_kb = (cfg.edram.leakage_mw_per_kb if cfg.use_edram
                     else cfg.edram.sram_leakage_mw_per_kb)
        leakage_j = mw_per_kb * 1e-3 * (cfg.onchip_bits / 8.0 / 1024.0) \
            * latency_s
    energy_j = compute_j + memory_j + leakage_j
    tokens = max(1, stats.tokens_served)
    lat = sorted(stats.latencies_s)
    serving = {
        "policy": arm.kv_policy,
        "seed": arm.traffic.seed,
        "arrival_per_s": arm.traffic.arrival_per_s,
        "max_batch": arm.traffic.max_batch,
        "requests": arm.traffic.n_requests,
        "requests_completed": stats.requests_completed,
        "requests_preempted": stats.requests_preempted,
        "tokens_served": stats.tokens_served,
        "prefill_tokens": stats.prefill_tokens,
        "tokens_per_s": stats.tokens_served / latency_s
        if latency_s > 0 else 0.0,
        "j_per_token": energy_j / tokens,
        "latency_p50_s": _percentile(lat, 0.50),
        "latency_p95_s": _percentile(lat, 0.95),
        "latency_mean_s": sum(lat) / len(lat) if lat else 0.0,
        "kv_entries_evicted": stats.kv_entries_evicted,
        "kv_entries_recomputed": stats.kv_entries_recomputed,
        "reads_dropped": stats.reads_dropped,
        "restore_j": ctrl.restore_j if ctrl is not None else 0.0,
    }
    if ctx.recorder is not None:
        ctx.recorder.meta.setdefault("arm", arm.name)
        ctx.recorder.counter("compute_j", latency_s, compute_j)
        ctx.recorder.counter("leakage_j", latency_s, leakage_j)
        ctx.recorder.counter("energy_j", latency_s, energy_j)
    from repro.sim.pipeline import _config_dict, _memory_dict
    ctx.report = ArmReport(
        arm=arm.name,
        reversible=False,
        latency_s=latency_s,
        energy_j=energy_j,
        compute_j=compute_j,
        memory_j=memory_j,
        scalar_memory_j=0.0,
        oracle_rel_err=0.0,
        stall_s=stall_s,
        max_lifetime_s=ctx.max_lifetime_s,
        refresh_free=rf,
        peak_live_bits=ctx.peak_live_bits,
        offchip_bits=offchip_bits,
        iters_to_target=None,
        tta_s=None,
        eta_j=None,
        timing=ctrl.timing if ctrl is not None else "scalar",
        refresh_stall_s=ctrl.refresh_stall_s if ctrl is not None else 0.0,
        refresh_hidden_j=ctrl.refresh_hidden_j if ctrl is not None else 0.0,
        leakage_j=leakage_j,
        rows_refreshed=ctrl.rows_refreshed if ctrl is not None else 0,
        row_hidden_frac=ctrl.row_hidden_frac if ctrl is not None else 0.0,
        freq_hz=ctx.freq_hz or cfg.freq_hz,
        pulse_exceeds_retention=(ctrl.pulse_exceeds_retention
                                 if ctrl is not None else False),
        timeline=(dict(ctrl.timeline)
                  if ctrl is not None and ctrl.timeline else {}),
        serving=serving,
        config=_config_dict(arm),
        memory=_memory_dict(ctrl),
        controller=ctrl,
        trace=ctx.recorder,
    )


SERVE_TIMELINE_PIPELINE = Pipeline((
    ("schedule", stage_serve_schedule),
    ("cost", stage_serve_cost),
    ("trace", stage_serve_trace),
    ("memory", stage_timeline),
    ("energy", stage_serve_energy),
))

SERVE_ADDITIVE_PIPELINE = SERVE_TIMELINE_PIPELINE.with_stage(
    "memory", stage_memory)


# the serving family, registered next to the Fig-24 training arms
for _policy in KV_POLICIES:
    register_arm(serve_arm(_policy))
del _policy
