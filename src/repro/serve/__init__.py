"""``repro.serve`` — eDRAM KV-cache serving simulation under traffic.

CAMEL's training story holds because activations are *transient*: a
value's producer→consumer window sits under the eDRAM retention floor,
so the branch trains refresh-free.  Serving inverts that — a KV-cache
entry is written once and re-read on **every** subsequent decode step of
its session, so its lifetime is the session's, orders of magnitude past
retention.  Kelle (arXiv 2510.16040) co-designs exactly this regime:
refresh the cache, skip refreshes that a read just performed, or drop /
re-derive entries instead of refreshing.  This package models those
policies on CAMEL's memory substrate, end to end under production-style
traffic::

    from repro import sim

    rep = sim.run(sim.get_arm("Serve/skip"))     # timeline model, eDRAM
    rep.serving["tokens_per_s"], rep.serving["j_per_token"]

Layers (each importable on its own):

``repro.serve.model``
    :class:`ServeModel` — the decoder LM as the memory system sees it:
    MACs per token, KV values per cache entry.
``repro.serve.traffic``
    :class:`TrafficSpec` / :func:`requests` — deterministic seeded
    Poisson arrivals + request mix + continuous-batching limits.
``repro.serve.engine``
    :func:`lower_traffic` — the decode-trace generator: traffic → one
    interleaved op schedule + per-tensor event stream, with the KV
    policy (:data:`KV_POLICIES`) applied inline.
``repro.serve.pipeline``
    :class:`ServeArm` + the serving pipelines — serving-specific
    schedule/cost/trace/energy stages around the **unchanged** memory
    stage, so bank/refresh/DVFS modeling, ``granularity="row"``, the
    flight recorder, and ``repro.obs.reconcile`` all apply verbatim.

Importing this package registers the serving arm family
(``Serve/always`` ``Serve/skip`` ``Serve/evict`` ``Serve/recompute``)
next to the Fig-24 training arms; ``repro.sim`` imports it, so
``sim.get_arm("Serve/...")`` always works.  See ``docs/serving.md``.
"""
from repro.serve.engine import (KV_POLICIES, ServeStats, ServeTrace,
                                lower_traffic)
from repro.serve.model import ServeModel
from repro.serve.pipeline import (POLICY_SYSTEM, SERVE_ADDITIVE_PIPELINE,
                                  SERVE_TIMELINE_PIPELINE, ServeArm,
                                  serve_arm)
from repro.serve.traffic import Request, TrafficSpec, requests

__all__ = [
    "KV_POLICIES", "POLICY_SYSTEM", "Request", "SERVE_ADDITIVE_PIPELINE",
    "SERVE_TIMELINE_PIPELINE", "ServeArm", "ServeModel", "ServeStats",
    "ServeTrace", "TrafficSpec", "lower_traffic", "requests", "serve_arm",
]
