"""Deterministic seeded serving traffic (request mix + arrival process).

A :class:`TrafficSpec` is frozen and hashable, so it rides on a frozen
``ServeArm`` and crosses the ``sim.sweep`` process pool; the arrival
process is a plain ``random.Random(seed)`` Poisson stream, so the same
spec always lowers to the *identical* trace (property-tested in
tests/test_serve_props.py).

Time is seconds on the simulation timeline.  Serving ops are
microsecond-scale on the modeled array, so interesting arrival rates sit
in the 10³–10⁵ requests/s range: well below that, sessions never
overlap (the continuous-batching scheduler degenerates to one slot and
every KV entry is re-read within an op time); well above it, the batch
saturates and per-session decode gaps stretch past the eDRAM retention
floor — which is exactly the regime where the KV policies diverge.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: arrive, prefill the prompt, decode
    ``gen_len`` tokens, release the session's cache."""
    rid: int
    arrival_s: float
    prompt_len: int
    gen_len: int


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Seeded arrival process + request mix + slot-scheduler limits.

    ``max_batch`` is the continuous-batching slot count: at most that
    many sessions decode concurrently; further arrivals queue.
    ``preempt_after`` (sessions that have decoded at least that many
    tokens may be preempted — cache evicted, session killed — to admit
    a queued request when every slot is busy) models session churn;
    ``None`` disables preemption.
    """
    seed: int = 0
    n_requests: int = 10
    arrival_per_s: float = 2.0e4
    prompt_lens: Tuple[int, ...] = (4, 8)
    gen_lens: Tuple[int, ...] = (4, 8)
    max_batch: int = 4
    preempt_after: Optional[int] = None


def requests(spec: TrafficSpec) -> Tuple[Request, ...]:
    """The spec's concrete request stream, in arrival order.

    Inter-arrival times are exponential at ``arrival_per_s``;
    prompt/generation lengths draw uniformly from the mix tuples.  All
    randomness comes from one ``random.Random(spec.seed)``, so equal
    specs yield equal streams.
    """
    rng = random.Random(spec.seed)
    t = 0.0
    out = []
    for rid in range(spec.n_requests):
        t += rng.expovariate(spec.arrival_per_s)
        out.append(Request(rid=rid, arrival_s=t,
                           prompt_len=rng.choice(spec.prompt_lens),
                           gen_len=rng.choice(spec.gen_lens)))
    return tuple(out)
