"""Declarative system arms (CAMEL Fig 24) and the arm registry.

An :class:`Arm` is everything ``sim.run`` needs, frozen in one place: a
workload (either a parametric :class:`WorkloadSpec` or explicit
``DuBlockSpec`` blocks), the :class:`~repro.core.hwmodel.SystemConfig`
(array size, memory tech, refresh/alloc policies), the training pattern
(reversible or whole-iteration buffering), and the measured
iterations-to-target that scale per-iteration cost into TTA/ETA.

The registry ships the paper's four arms:

=============  ==========  ===========================  ================
name           pattern     memory system                iters to target
=============  ==========  ===========================  ================
DuDNN+CAMEL    reversible  12×32 KB eDRAM, selective    1000
FR+SRAM        buffered    4×48 KB SRAM + off-chip      1000
CA+CAMEL       reversible  12×32 KB eDRAM, selective    2500 (§VI-F)
BO+CAMEL       reversible  12×32 KB eDRAM, selective    never reaches
=============  ==========  ===========================  ================

``register_arm`` adds custom arms (sweep points, ablations) to the same
namespace ``sim.get_arm`` resolves from.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core import hwmodel as hw
from repro.core import lifetime as lt
from repro.sim.cost import CostModel

WORKLOAD_KINDS = ("duplex_cnn", "lm_branch")

# convergence behaviour measured in benchmarks/table2 at small scale
# (§VI-F): CA needs ~2.5× the iterations; BO never reaches the target.
ITERS_TARGET = 1000.0
ITERS_CHAIN = 2500.0


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Parametric DuDNN workload (resolved to ``DuBlockSpec`` blocks).

    For ``kind="lm_branch"``, ``spatial`` is the pooled sequence length,
    ``c_branch`` the branch width d_branch and ``c_backbone`` d_model.
    """
    kind: str = "duplex_cnn"
    n_blocks: int = 6
    batch: int = 48
    spatial: int = 7
    c_branch: int = 48
    c_backbone: int = 160
    kernel: int = 3

    def blocks(self) -> Tuple[lt.DuBlockSpec, ...]:
        if self.kind == "duplex_cnn":
            return tuple(lt.duplex_block_specs(
                self.n_blocks, self.batch, self.spatial,
                self.c_branch, self.c_backbone, self.kernel))
        if self.kind == "lm_branch":
            return tuple(lt.lm_branch_block_specs(
                self.n_blocks, self.batch, self.spatial,
                self.c_branch, self.c_backbone))
        raise ValueError(f"unknown workload kind {self.kind!r}; "
                         f"choose from {WORKLOAD_KINDS}")


@dataclasses.dataclass(frozen=True)
class Arm:
    """One system arm: workload + system config + memory policies.

    ``cost`` is the timing policy — the pluggable cost model
    (``repro.sim.cost``) that turns op *work* into seconds at an
    operating point.  ``None`` means :class:`~repro.sim.cost.FixedClock`
    at the system's nominal ``freq_hz`` (bit-identical to the
    pre-cost-model pipeline); a :class:`~repro.sim.cost.DVFSState`
    evaluates the same arm at a different frequency/voltage point while
    retention deadlines stay wall-clock.

    The memory policies ride on the ``system``
    (:class:`~repro.core.hwmodel.SystemConfig`): ``refresh_policy``
    (always/none/selective), ``refresh_granularity`` ("bank" pulses one
    whole bank per retention tick; "row" pulses each occupied wordline
    independently, the paper controller's discipline), and
    ``alloc_policy`` — e.g.
    ``arm.with_system(refresh_granularity="row")``.
    """
    name: str
    system: hw.SystemConfig = hw.SystemConfig()
    reversible: bool = True
    workload: Optional[WorkloadSpec] = WorkloadSpec()
    blocks: Optional[Tuple[lt.DuBlockSpec, ...]] = None
    iters_to_target: Optional[float] = ITERS_TARGET
    cost: Optional[CostModel] = None

    def resolve_blocks(self) -> Tuple[lt.DuBlockSpec, ...]:
        """Explicit ``blocks`` win over the parametric ``workload``."""
        if self.blocks is not None:
            return tuple(self.blocks)
        if self.workload is None:
            raise ValueError(
                f"arm {self.name!r} has neither blocks nor workload")
        return self.workload.blocks()

    def with_workload(self, **fields) -> "Arm":
        """New arm with workload fields replaced (clears a blocks override)."""
        wl = dataclasses.replace(self.workload or WorkloadSpec(), **fields)
        return dataclasses.replace(self, workload=wl, blocks=None)

    def with_system(self, **fields) -> "Arm":
        """New arm with SystemConfig fields replaced."""
        return dataclasses.replace(
            self, system=dataclasses.replace(self.system, **fields))

    def with_cost(self, cost: Optional[CostModel]) -> "Arm":
        """New arm simulated under ``cost`` (a ``repro.sim.cost`` model;
        ``None`` restores the FixedClock default)."""
        return dataclasses.replace(self, cost=cost)


# ---------------------------------------------------------------- registry

ARM_REGISTRY: dict = {}


def register_arm(arm: Arm, overwrite: bool = False) -> Arm:
    if arm.name in ARM_REGISTRY and not overwrite:
        raise ValueError(f"arm {arm.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    ARM_REGISTRY[arm.name] = arm
    return arm


def get_arm(name: str) -> Arm:
    try:
        return ARM_REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arm {name!r}; registered: "
                       f"{', '.join(sorted(ARM_REGISTRY))}") from None


def arms() -> Tuple[str, ...]:
    """Registered arm names, paper arms first."""
    return tuple(ARM_REGISTRY)


register_arm(Arm(name="DuDNN+CAMEL", system=hw.SystemConfig(),
                 reversible=True, iters_to_target=ITERS_TARGET))
register_arm(Arm(name="FR+SRAM", system=hw._SRAM_ONLY,
                 reversible=False, iters_to_target=ITERS_TARGET))
register_arm(Arm(name="CA+CAMEL", system=hw.SystemConfig(name="CA+CAMEL"),
                 reversible=True, iters_to_target=ITERS_CHAIN))
register_arm(Arm(name="BO+CAMEL", system=hw.SystemConfig(name="BO+CAMEL"),
                 reversible=True, iters_to_target=None))
