"""Pluggable cost models: op *work* → op *time* at an operating point.

The schedule builders (``core.schedule``) emit ops that carry **work**
(MAC counts, port words, DMA bits — :class:`~repro.core.schedule.OpWork`)
instead of baked durations.  A *cost model* turns that work into seconds,
which is what makes timing frequency-dependent: under DVFS the compute
and bank-port clocks stretch while the eDRAM retention deadlines — a
wall-clock, temperature-set leakage phenomenon (CAMEL §VI-D, Fig 22) —
do not.  Refresh hiding and the refresh-free verdict therefore change
across operating points (see ``sim.sweep(freqs=...)``).

Two models ship:

:class:`FixedClock`
    The default — one fixed frequency (the arm's ``SystemConfig.freq_hz``
    unless overridden), nominal energy.  Bit-identical to the pre-cost-
    model pipeline at 500 MHz (golden-pinned in tests/test_cost.py).
:class:`DVFSState`
    A frequency/voltage operating point.  Compute time scales ∝ 1/f;
    *dynamic* compute energy scales with the supply, (V/V_nom)² per MAC
    (dynamic power ∝ V²f — for fixed work the f cancels).  The memory
    macro stays on its characterized 0.8 V rail: access/refresh pJ/bit
    and the retention curve are **not** rescaled, i.e. leakage and
    retention are held in wall-clock.

Anything with ``resolve(system) -> OperatingPoint`` plugs in
(:class:`CostModel` protocol); richer models can subclass
:class:`OperatingPoint` and override :meth:`OperatingPoint.op_seconds`
for non-linear work→time laws.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Protocol, runtime_checkable

from repro.core.schedule import Op, OpWork

#: reference rails for the shipped models (paper's eDRAM point, §V-D)
VDD_NOM = 0.8
FREQ_NOM = 500e6


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """A resolved operating point: the clock every on-chip timing quantity
    is priced against, plus the dynamic-energy multiplier on compute.

    ``freq_hz`` drives op compute time (via the effective MAC rate), bank
    port service time, and refresh pulse duration; ``offchip_bw_bps`` is
    the wall-clock DMA bandwidth (does not scale with the core clock).
    """
    freq_hz: float
    compute_scale: float = 1.0     # × on MAC pJ (dynamic, ∝ V²)
    offchip_bw_bps: float = 0.0    # wall-clock DMA bandwidth (bits/s)
    label: str = "fixed"

    def op_seconds(self, work: OpWork, mac_rate_s: float) -> float:
        """Seconds one op's ``work`` takes at this point.

        ``mac_rate_s`` is the effective MAC/s of the systolic array *at
        this point's clock* (``core.lifetime.array_throughput``).  The op
        finishes when its slowest work component does: MAC stream, any
        explicit port words (one word/cycle), and any off-chip DMA
        payload at wall-clock bandwidth.
        """
        mac_s = work.macs / mac_rate_s if mac_rate_s > 0.0 else 0.0
        port_s = (work.port_words / self.freq_hz
                  if self.freq_hz > 0.0 else 0.0)
        dma_s = (work.dma_bits / self.offchip_bw_bps
                 if work.dma_bits and self.offchip_bw_bps > 0.0 else 0.0)
        return max(mac_s, port_s, dma_s)


@runtime_checkable
class CostModel(Protocol):
    """The pluggable contract: resolve a ``SystemConfig`` into an
    :class:`OperatingPoint`.  Implementations must be frozen/picklable
    dataclasses so arms carrying them cross the ``sim.sweep`` process
    pool."""

    def resolve(self, system) -> OperatingPoint:        # pragma: no cover
        ...


@dataclasses.dataclass(frozen=True)
class FixedClock:
    """The default cost model: one fixed clock, nominal energy.

    ``freq_hz=None`` reads the arm's ``SystemConfig.freq_hz`` (the one
    sanctioned consumer of that field — see the deprecation note in
    ``core.hwmodel``); a float pins a different clock at nominal voltage
    (pure underclock/overclock, no voltage scaling).
    """
    freq_hz: Optional[float] = None

    def resolve(self, system) -> OperatingPoint:
        f = self.freq_hz if self.freq_hz is not None else system.freq_hz
        if f <= 0.0:
            raise ValueError(f"FixedClock needs a positive clock, got {f}")
        return OperatingPoint(freq_hz=f, compute_scale=1.0,
                              offchip_bw_bps=system.offchip_bw_bps,
                              label=f"fixed@{f / 1e6:.0f}MHz")


@dataclasses.dataclass(frozen=True)
class DVFSState:
    """A DVFS operating point: frequency + supply voltage.

    ``vdd=None`` follows a modeled linear f–V curve with a near-threshold
    floor: ``V = V_nom · (floor + (1 − floor) · f / f_nom)``.  Dynamic
    compute energy scales ``(V/V_nom)²``; leakage-driven quantities (the
    retention curve, hence refresh deadlines and refresh energy per
    wall-clock second) are deliberately *not* rescaled — the eDRAM macro
    stays at its characterized rail.
    """
    freq_hz: float
    vdd: Optional[float] = None
    vdd_nom: float = VDD_NOM
    freq_nom: float = FREQ_NOM
    vdd_floor: float = 0.45        # fraction of vdd_nom as f → 0

    def voltage(self) -> float:
        """The resolved supply (V) at this point."""
        if self.vdd is not None:
            return self.vdd
        frac = self.vdd_floor + (1.0 - self.vdd_floor) * (
            self.freq_hz / self.freq_nom)
        return self.vdd_nom * frac

    def resolve(self, system) -> OperatingPoint:
        if self.freq_hz <= 0.0:
            raise ValueError(
                f"DVFSState needs a positive clock, got {self.freq_hz}")
        v = self.voltage()
        return OperatingPoint(freq_hz=self.freq_hz,
                              compute_scale=(v / self.vdd_nom) ** 2,
                              offchip_bw_bps=system.offchip_bw_bps,
                              label=f"dvfs@{self.freq_hz / 1e6:.0f}MHz/"
                                    f"{v:.2f}V")


def resolve_cost(cost: Optional[CostModel], system) -> OperatingPoint:
    """The operating point an arm's ``cost`` policy implies
    (``None`` → :class:`FixedClock` at the system's nominal clock)."""
    return (cost if cost is not None else FixedClock()).resolve(system)


def op_timer(point: OperatingPoint,
             mac_rate_s: float) -> Callable[[Op], float]:
    """The per-op work→seconds resolver ``core.schedule.simulate``
    consumes: explicit ``Op.duration_s`` pins win (legacy ops), all other
    ops are priced by ``point.op_seconds`` at ``mac_rate_s``."""
    def seconds(op: Op) -> float:
        if op.duration_s is not None:
            return op.duration_s
        return point.op_seconds(op.work, mac_rate_s)
    return seconds


def cost_dict(cost: Optional[CostModel]) -> dict:
    """JSON-safe description of a cost model for ``ArmReport.config``."""
    model = cost if cost is not None else FixedClock()
    d = dataclasses.asdict(model) if dataclasses.is_dataclass(model) else {}
    return {"model": type(model).__name__, **d}
