"""Structured simulation reports (the ``repro.sim`` pipeline's output).

:class:`ArmReport` is the single result type of ``sim.run(arm)``: flat
scalar fields for the headline numbers, plus two plain-dict payloads
(``config`` — the fully resolved arm, ``memory`` — the controller's
per-bank breakdown).  Reports round-trip through ``to_dict()`` /
``from_dict()`` and plain JSON losslessly, so benchmark records and sweep
artifacts are self-describing.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArmReport:
    """One system arm's per-iteration cost and TTA/ETA projection."""
    arm: str
    reversible: bool
    latency_s: float
    energy_j: float
    compute_j: float
    memory_j: float
    # the scalar closed-form path, kept as a cross-validation oracle
    scalar_memory_j: float
    oracle_rel_err: float
    stall_s: float
    max_lifetime_s: float
    refresh_free: bool
    peak_live_bits: float
    offchip_bits: float
    # convergence-scaled projections (§VI-F); None when the arm has no
    # iters_to_target (BO never reaches the accuracy target)
    iters_to_target: Optional[float]
    tta_s: Optional[float]
    eta_j: Optional[float]
    # which stall model produced stall_s: "additive" (per-op overshoot
    # summed), "timeline" (closed-loop event-interleaved walk), or
    # "scalar" (no controller — closed forms only)
    timing: str = "additive"
    # refresh time the schedule actually sees (s): under the timeline
    # model only pulses with no bank-idle window stall; the energy of the
    # hidden ones is refresh_hidden_j (J) — charged, but costing no time
    refresh_stall_s: float = 0.0
    refresh_hidden_j: float = 0.0
    # on-chip tier leakage charged over the iteration's wall-clock
    # latency (J); 0.0 unless SystemConfig.charge_leakage is set
    leakage_j: float = 0.0
    # row-granular refresh (SystemConfig.refresh_granularity="row"):
    # row pulses emitted and the share of them hidden in idle gaps;
    # both stay 0 under the default bank granularity
    rows_refreshed: int = 0
    row_hidden_frac: float = 0.0
    # the resolved operating point's clock (Hz) — the arm's cost model
    # decides it (FixedClock at SystemConfig.freq_hz by default); 0.0 on
    # records written before the cost-model API
    freq_hz: float = 0.0
    # some bank's refresh pulse outlasts its (wall-clock) retention
    # interval: refresh there can never hide under compute
    pulse_exceeds_retention: bool = False
    # timeline-model summary (makespan, pushback, pulse placement counts);
    # empty dict under additive/scalar timing
    timeline: dict = dataclasses.field(default_factory=dict)
    # per-tier breakdown (hybrid SRAM+eDRAM arms only): one JSON-safe
    # summary dict per memory tier (name, cell, capacity, traffic/
    # refresh/leakage energies — see repro.memory.tiers).  Empty tuple
    # on single-tier arms — serialized only when non-empty, so their
    # historical to_dict() shape is unchanged
    tiers: tuple = ()
    # serving-workload summary (repro.serve arms only): tokens served,
    # tokens/s, J/token, per-request latency percentiles, KV-policy
    # counters (entries evicted/recomputed, restore_j).  Empty dict on
    # training arms — serialized only when non-empty, so their historical
    # to_dict() shape is unchanged
    serving: dict = dataclasses.field(default_factory=dict)
    # fully resolved inputs and the controller's breakdown, JSON-safe
    config: dict = dataclasses.field(default_factory=dict)
    memory: dict = dataclasses.field(default_factory=dict)
    # the live ControllerReport object for python consumers; not part of
    # the serialized form and excluded from equality
    controller: object = dataclasses.field(
        default=None, compare=False, repr=False)
    # stage wall-clock profile from sim.run(profile=True):
    # {"stages": {name: seconds}, "total_s": float}.  Machine-local
    # measurement, so excluded from equality; serialized only when
    # non-empty (records written without profiling keep their exact
    # historical to_dict() shape)
    profile: dict = dataclasses.field(default_factory=dict, compare=False)
    # the live repro.obs.SpanRecorder from sim.run(trace=...); like
    # controller, a python-side object outside the serialized form
    trace: object = dataclasses.field(
        default=None, compare=False, repr=False)

    _SCALARS = ("arm", "reversible", "latency_s", "energy_j", "compute_j",
                "memory_j", "scalar_memory_j", "oracle_rel_err", "stall_s",
                "max_lifetime_s", "refresh_free", "peak_live_bits",
                "offchip_bits", "iters_to_target", "tta_s", "eta_j",
                "timing", "refresh_stall_s", "refresh_hidden_j",
                "leakage_j", "rows_refreshed", "row_hidden_frac",
                "freq_hz", "pulse_exceeds_retention")

    def to_dict(self) -> dict:
        """Plain-JSON form (drops the live ``controller``/``trace``
        objects; includes ``profile`` only when one was recorded)."""
        d = {k: getattr(self, k) for k in self._SCALARS}
        d["timeline"] = self.timeline
        d["config"] = self.config
        d["memory"] = self.memory
        if self.tiers:
            d["tiers"] = list(self.tiers)
        if self.serving:
            d["serving"] = self.serving
        if self.profile:
            d["profile"] = self.profile
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ArmReport":
        known = {f.name for f in dataclasses.fields(cls)} - {"controller",
                                                             "trace"}
        kw = {k: v for k, v in d.items() if k in known}
        if "tiers" in kw:
            # JSON round-trip turns the tuple into a list; restore it so
            # from_dict(to_dict(r)) == r holds field-for-field
            kw["tiers"] = tuple(kw["tiers"])
        return cls(**kw)
