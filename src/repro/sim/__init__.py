"""``repro.sim`` — the unified arm/pipeline simulation API (CAMEL §V/§VI).

One entry point for every system arm::

    from repro import sim

    report = sim.run(sim.get_arm("DuDNN+CAMEL"))        # ArmReport
    reports = sim.sweep([sim.get_arm(n) for n in sim.arms()])
    fr = sim.run(sim.get_arm("FR+SRAM").with_workload(n_blocks=4))

Every arm — including the irreversible FR/SRAM baseline — executes through
the same staged pipeline (schedule → trace → memory-controller replay →
energy/latency), so the bank-level ``repro.memory`` controller models all
of them; the scalar closed forms ride along as a cross-validation oracle
(``ArmReport.oracle_rel_err``).  Reports are plain-dict/JSON
round-trippable via ``to_dict``/``from_dict``.

Custom arms are frozen dataclasses (``sim.Arm``) and can be registered
(``sim.register_arm``); custom pipelines swap stages
(``sim.Pipeline.with_stage``) — the hook the planned closed-loop stall
model uses.
"""
from repro.sim.arm import (ARM_REGISTRY, ITERS_CHAIN, ITERS_TARGET,
                           WORKLOAD_KINDS, Arm, WorkloadSpec, arms, get_arm,
                           register_arm)
from repro.sim.pipeline import (DEFAULT_PIPELINE, DEFAULT_STAGES, Pipeline,
                                SimContext, run, sweep)
from repro.sim.report import ArmReport

__all__ = [
    "ARM_REGISTRY", "Arm", "ArmReport", "DEFAULT_PIPELINE", "DEFAULT_STAGES",
    "ITERS_CHAIN", "ITERS_TARGET", "Pipeline", "SimContext", "WORKLOAD_KINDS",
    "WorkloadSpec", "arms", "get_arm", "register_arm", "run", "sweep",
]
