"""``repro.sim`` — the unified arm/pipeline simulation API (CAMEL §V/§VI).

One entry point for every system arm::

    from repro import sim

    report = sim.run(sim.get_arm("DuDNN+CAMEL"))        # ArmReport
    reports = sim.sweep([sim.get_arm(n) for n in sim.arms()])
    fr = sim.run(sim.get_arm("FR+SRAM").with_workload(n_blocks=4))

Every arm — including the irreversible FR/SRAM baseline — executes through
the same staged pipeline (schedule → trace → memory-controller replay →
energy/latency), so the bank-level ``repro.memory`` controller models all
of them; the scalar closed forms ride along as a cross-validation oracle
(``ArmReport.oracle_rel_err``).  Reports are plain-dict/JSON
round-trippable via ``to_dict``/``from_dict``.

Two stall models share the pipeline: ``sim.run(arm)`` defaults to the
closed-loop event-interleaved **timeline** model (``repro.sim.timeline``
— refresh pulses hide in bank-idle windows, port overshoot pushes back
successor ops) and ``sim.run(arm, timing="additive")`` keeps the PR-2
additive model as a bit-compatible cross-validation baseline.
``sim.sweep`` fans a grid of arms × workloads × temperatures over a
process pool (``parallel=N``) with deterministic result ordering.

Op *work* and op *time* are split by a pluggable cost model
(``repro.sim.cost``): ops carry MAC/port/DMA work and the arm's ``cost``
policy — ``FixedClock`` (default, the nominal 500 MHz point) or
``DVFSState`` (frequency/voltage operating points, dynamic energy ∝ V²,
retention deadlines held in wall-clock) — prices it into seconds.
``sim.sweep(..., freqs=[...])`` adds the operating-point grid axis.

Custom arms are frozen dataclasses (``sim.Arm``) and can be registered
(``sim.register_arm``); custom pipelines swap stages
(``sim.Pipeline.with_stage``) — exactly how the timeline model installs
itself.  See ``docs/sim-api.md`` for the full reference.

Observability is opt-in and observation-only: ``sim.run(arm,
trace=True)`` threads a ``repro.obs.SpanRecorder`` through the engine
(op/port/refresh/spill spans + counter series, exportable to
Perfetto/Chrome tracing and exactly reconcilable against the report);
``sim.run(arm, profile=True)`` wall-clocks the pipeline stages into
``report.profile``.  Either way every report number stays bit-identical.
See ``docs/observability.md``.
"""
from repro.sim.arm import (ARM_REGISTRY, ITERS_CHAIN, ITERS_TARGET,
                           WORKLOAD_KINDS, Arm, WorkloadSpec, arms, get_arm,
                           register_arm)
from repro.sim.cost import (CostModel, DVFSState, FixedClock,
                            OperatingPoint, op_timer, resolve_cost)
from repro.sim.pipeline import (DEFAULT_PIPELINE, DEFAULT_STAGES,
                                DEFAULT_TIMING, TIMINGS, Pipeline,
                                SimContext, resolve_pipeline, run, sweep)
from repro.sim.report import ArmReport
from repro.sim.timeline import (TIMELINE_PIPELINE, replay_timeline,
                                stage_timeline)
from repro.sim.hybrid import HYBRID_SPLIT, hybrid_arm, hybrid_system

__all__ = [
    "ARM_REGISTRY", "Arm", "ArmReport", "CostModel", "DEFAULT_PIPELINE",
    "DEFAULT_STAGES", "DEFAULT_TIMING", "DVFSState", "FixedClock",
    "HYBRID_SPLIT", "ITERS_CHAIN", "ITERS_TARGET", "OperatingPoint",
    "Pipeline", "SimContext", "TIMELINE_PIPELINE", "TIMINGS",
    "WORKLOAD_KINDS", "WorkloadSpec", "arms", "get_arm", "hybrid_arm",
    "hybrid_system", "op_timer", "register_arm", "replay_timeline",
    "resolve_cost", "resolve_pipeline", "run", "stage_timeline", "sweep",
]

# side-effect: registers the serving arm family (Serve/always|skip|
# evict|recompute, docs/serving.md) so sim.get_arm resolves them.  Last,
# because repro.serve imports from the sim submodules above.
import repro.serve  # noqa: E402,F401  isort:skip
