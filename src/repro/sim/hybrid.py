"""The ``Hybrid+CAMEL`` arm family: reversible training on a mixed
SRAM+eDRAM memory at an iso-area capacity split (MCAIMem, arXiv
2312.03559, on the CAMEL §V stack).

The Fig-24 comparison has two homogeneous memory endpoints — the
all-eDRAM ``DuDNN+CAMEL`` arm (dense, but over-retention tensors force
refresh at high temperature) and the all-SRAM ``FR+SRAM`` baseline
(refresh-free, but half the capacity per area and an irreversible
training recipe that spills to DRAM).  :func:`hybrid_arm` fills in the
continuum: same reversible DuDNN workload as ``DuDNN+CAMEL``, but the
bank array is split at equal silicon area between a refresh-free SRAM
tier and a dense eDRAM tier (:func:`repro.memory.tiers.iso_area_tiers`),
with the ``lifetime_tiered`` policy routing over-retention tensors to
SRAM and transients to eDRAM.  At an interior split the hybrid keeps
(most of) eDRAM's capacity while paying zero refresh — the mixed-cell
win ``benchmarks/tier_sweep.py`` sweeps and ``tests/test_tiers.py``
pins.

The endpoints delegate to the registered arms themselves
(``hybrid_arm(0.0) is get_arm("DuDNN+CAMEL")``), so endpoint records in
``BENCH_tiers.json`` match the existing Fig-24 records exactly by
construction.
"""
from __future__ import annotations

import dataclasses

from repro.core import hwmodel as hw
from repro.memory.tiers import iso_area_tiers
from repro.sim.arm import ITERS_TARGET, Arm, get_arm, register_arm

# the canonical registered split: 1/4 of the array area as SRAM — enough
# for the DuDNN workload's over-retention tensors across the Fig-23
# temperature range, while keeping 3/4 of the area at eDRAM density
HYBRID_SPLIT = 0.25


def hybrid_system(sram_split: float, *,
                  name: str = "Hybrid+CAMEL") -> hw.SystemConfig:
    """A ``SystemConfig`` whose memory is the iso-area hybrid at
    ``sram_split`` (SRAM area share in [0, 1])."""
    base = hw.SystemConfig(name=name)
    tiers = iso_area_tiers(base.edram, sram_split,
                           sram_banks=base.sram_banks)
    return dataclasses.replace(
        base, tiers=tiers, alloc_policy="lifetime_tiered",
        use_edram=True,
        onchip_bits=sum(t.capacity_bits for t in tiers))


def hybrid_arm(sram_split: float = HYBRID_SPLIT) -> Arm:
    """The hybrid arm at one iso-area split.  The endpoints return the
    registered homogeneous arms themselves — ``DuDNN+CAMEL`` at
    ``sram_split=0`` (all-eDRAM) and ``FR+SRAM`` at ``sram_split=1``
    (all-SRAM at iso-area: exactly the FR baseline's 4×48 KB) — so
    endpoint comparisons are exact by construction, not approximately
    re-derived."""
    s = float(sram_split)
    if s <= 0.0:
        return get_arm("DuDNN+CAMEL")
    if s >= 1.0:
        return get_arm("FR+SRAM")
    return Arm(name=f"Hybrid+CAMEL@{s:g}", system=hybrid_system(s),
               reversible=True, iters_to_target=ITERS_TARGET)


register_arm(Arm(name="Hybrid+CAMEL",
                 system=hybrid_system(HYBRID_SPLIT),
                 reversible=True, iters_to_target=ITERS_TARGET))
