"""Closed-loop, event-interleaved memory timing (``timing="timeline"``).

The additive model (``memory.trace.replay``) charges two kinds of stall
*independently of when they happen*: an op's port overshoot is summed
into a global stall total, and every refresh pulse serializes against the
bank ports.  That is pessimistic in exactly the way CAMEL's pipeline is
not: on real hardware a refresh pulse fires whenever its bank is idle —
which, with compute-bound ops touching a few banks at a time, is most of
the time — and only *preempts* when its retention deadline arrives with
the bank still busy.

This module replaces the pipeline's ``memory`` stage with a
discrete-event engine that models that:

1.  **Closed-loop op walk** — ops execute in schedule order on one
    timeline; an op occupies its banks' ports for their service time
    (one word/cycle/port) and *pushes back every successor* until both
    its compute and its slowest port finish.  Per-bank busy intervals
    are recorded on :class:`~repro.memory.banks.BankState` as it walks.
2.  **Deadline-driven refresh placement** — for each bank the refresh
    policy would refresh, one pulse per retention interval is placed
    into a bank-idle window before its deadline
    (:meth:`RefreshScheduler.place_pulses`).  A placed pulse is *hidden*:
    its energy is charged (``refresh_hidden_j``) but it costs no time.
    Only pulses with no idle window stall (``refresh_stall_s``).

Refresh preemption is charged as a serialized tail rather than re-fed
into op start times (a second-order effect — an unhidden pulse is rare
and short next to an op); energy accounting is shared verbatim with the
additive model, so ``refresh_j``/``read_j``/``write_j`` agree bit-for-bit
between the two timings and only *time* moves.

Op durations, port service times and pulse widths all derive from the
arm's cost model (``repro.sim.cost`` — the pipeline's ``cost`` stage),
while retention deadlines stay wall-clock: under DVFS the idle windows
stretch/shrink against fixed deadlines, so pulse placement, the hiding
rate, and the refresh-free verdict are frequency-dependent
(``sim.sweep(freqs=...)`` sweeps this).  A bank whose pulse is longer
than its retention interval can never hide — surfaced as
``pulse_exceeds_retention`` instead of silently stalling every interval.
"""
from __future__ import annotations

import math

from repro.memory import trace as mtr
from repro.memory.banks import port_service_s
from repro.memory.refresh import placement_interval
from repro.sim.arm import Arm
from repro.sim.pipeline import (DEFAULT_PIPELINE, SimContext,
                                memory_config)


def closed_loop_walk(core: mtr.ReplayCore, op_schedule,
                     recorder=None) -> float:
    """Walk ``op_schedule`` (``[(name, start_s, end_s), ...]`` in
    execution order) against the replay core's per-op bank-word tables;
    returns the makespan in seconds.

    Each op starts when its predecessor's compute *and* slowest port
    finish — port overshoot pushes back every successor instead of being
    summed into a side total.  Zero-duration ops are elementwise
    adds/copies fused into the producing MAC op's pipeline (Fig 12):
    they neither occupy ports nor advance time, matching the additive
    model's treatment.  Records per-bank busy intervals via
    ``BankState.occupy_port`` as a side effect.

    ``recorder`` (a ``repro.obs.SpanRecorder``) additionally gets one
    ``op`` span per executed op on the pushed-back timeline (with its
    unconstrained schedule position and pushback in args) and one
    ``port`` span per (op, bank) covering the slowest of the op's
    read/write services there.  Observation only — the walk itself is
    bit-identical with or without it.
    """
    banks = core.alloc.banks
    t = 0.0
    for name, start0, end0 in op_schedule:
        dur = end0 - start0
        if dur <= 0.0:
            continue
        start = t
        end = start + dur
        ports = {} if recorder is not None else None
        for table, io in ((core.op_read_words, "read_words"),
                          (core.op_write_words, "write_words")):
            per = table.get(name)
            if not per:
                continue
            for b_idx, words in per.items():
                busy = port_service_s(words, core.freq_hz)
                if busy > 0.0:
                    banks[b_idx].occupy_port(start, start + busy)
                    end = max(end, start + busy)
                    if ports is not None:
                        slot = ports.setdefault(
                            b_idx, {"end": start,
                                    "read_words": 0, "write_words": 0})
                        slot["end"] = max(slot["end"], start + busy)
                        slot[io] += words
        if recorder is not None:
            for b_idx in sorted(ports):
                slot = ports[b_idx]
                recorder.span("port", name, start, slot["end"],
                              bank=b_idx,
                              read_words=slot["read_words"],
                              write_words=slot["write_words"])
            recorder.span("op", name, start, end,
                          sched_start_s=start0, sched_end_s=end0,
                          pushback_s=end - (start + dur))
        t = end
    return t


def replay_timeline(events, cfg, *, op_schedule, temp_c: float,
                    duration_s: float, refresh_policy: str = "selective",
                    alloc_policy: str = "pingpong", freq_hz: float = 500e6,
                    sample_scale: float = 1.0, refresh_guard: float = 1.0,
                    retention_s=None, granularity: str = "bank",
                    reads_restore: bool = False,
                    recorder=None,
                    backend: str = "python",
                    tiers=None) -> mtr.ControllerReport:
    """Replay ``events`` with the closed-loop timeline model.

    Same contract as :func:`repro.memory.trace.replay` (energies in J,
    stalls in s), plus ``op_schedule`` — the ordered
    ``[(name, start_s, end_s), ...]`` list the engine walks.  The
    returned report has ``timing="timeline"``, the
    ``conflict_stall_s``/``refresh_stall_s`` split, ``refresh_hidden_j``,
    and a JSON-safe ``timeline`` summary (makespan, pulse placement
    counts, per-bank port-busy time).  ``granularity="row"`` switches the
    pulse unit to one occupied wordline — each tick's row pulses pack
    independently into the bank's idle gaps, so a near-full bank whose
    whole-bank pulse could never hide still hides refresh row by row
    (refresh energy is granularity-invariant; only stalls move).

    ``recorder`` (a ``repro.obs.SpanRecorder``) captures the engine's
    full event history — op/port spans from the walk, spill spans and
    occupancy counters from the replay core, one ``refresh`` (hidden) or
    ``refresh_stall`` (preempting) span per placed pulse, and per-bank
    refresh-energy counters — plus the reconciliation metadata
    ``repro.obs.reconcile`` needs.  Strictly observation: every number
    in the returned report is bit-identical with or without a recorder.

    ``backend="vector"`` runs the whole engine — replay core, closed-loop
    walk, pulse placement — on the numpy interval engine
    (``repro.memory.vector``); the report is bit-identical.  A recorder
    or a tiered memory system (``tiers=``) downgrades the request to the
    reference path with a logged warning (``mtr.resolve_backend``),
    since span recording and tier routing observe the scalar walks'
    per-event side effects.
    """
    backend = mtr.resolve_backend(backend, recorder, tiers=tiers)
    core = mtr.replay_core(
        events, cfg, temp_c=temp_c, duration_s=duration_s,
        refresh_policy=refresh_policy, alloc_policy=alloc_policy,
        freq_hz=freq_hz, sample_scale=sample_scale,
        refresh_guard=refresh_guard, retention_s=retention_s,
        granularity=granularity, reads_restore=reads_restore,
        recorder=recorder, backend=backend, tiers=tiers)

    if backend == "vector":
        from repro.memory import vector as vec
        makespan = vec.closed_loop_walk_vector(core, op_schedule)
        makespan = max(makespan, duration_s)
        conflict_stall_s = makespan - duration_s
        bank_pulses = vec.place_all_pulses_vector(core, makespan)
        decisions = mtr.account_refresh(
            core, duration_s,
            pulse_stats={i: (bp.count, bp.stall_s, bp.hidden_count)
                         for i, bp in bank_pulses.items()})
        n_pulses = sum(bp.count for bp in bank_pulses.values())
        hidden = sum(bp.hidden_count for bp in bank_pulses.values())
    else:
        makespan = closed_loop_walk(core, op_schedule, recorder=recorder)
        makespan = max(makespan, duration_s)
        conflict_stall_s = makespan - duration_s

        # place one pulse per retention tick into each refreshed bank's
        # idle windows on the *pushed-back* timeline; each bank asks the
        # scheduler that owns it (one per tier on hybrid cores — SRAM
        # tiers never refresh, so they place nothing)
        placements = {
            b.index: core.sched_for(b.index).place_pulses(
                b, makespan, core.freq_hz)
            for b in core.alloc.banks
            if core.sched_for(b.index).would_refresh(b)}
        decisions = mtr.account_refresh(core, duration_s,
                                        placements=placements)

        pulses = [p for ps in placements.values() for p in ps]
        # p.rows is the pulse multiplicity (an aggregated preempting run
        # of row pulses counts each of its rows)
        n_pulses = sum(p.rows for p in pulses)
        hidden = sum(p.rows for p in pulses if p.hidden)
    summary = {
        "makespan_s": makespan,
        "schedule_s": duration_s,
        "conflict_stall_s": conflict_stall_s,
        "refresh_stall_s": sum(d.stall_s for d in decisions),
        "pulses": n_pulses,
        "pulses_hidden": hidden,
        "granularity": granularity,
        "port_busy_s": [b.busy_s for b in core.alloc.banks],
        "ops": sum(1 for _, s, e in op_schedule if e > s),
    }
    if recorder is not None:
        for b_idx in sorted(placements):
            for p in placements[b_idx]:
                t0, t1 = placement_interval(p, core.freq_hz)
                recorder.span(
                    "refresh" if p.hidden else "refresh_stall",
                    f"pulse[{p.index}]", t0, t1, bank=b_idx,
                    tick=p.index, row=p.row, rows=p.rows, words=p.words,
                    stall_s=p.stall_s, deadline_s=p.deadline_s)
        for d in decisions:
            if d.refreshed:
                recorder.counter("refresh_j", makespan, d.refresh_j,
                                 bank=d.bank)
        recorder.counter("refresh_total_j", makespan,
                         sum(d.refresh_j for d in decisions))
        recorder.meta.update(
            timing="timeline", schedule_s=duration_s, makespan_s=makespan,
            freq_hz=core.freq_hz, granularity=granularity, temp_c=temp_c,
            refresh_policy=refresh_policy,
            interval_s=(core.sched.interval_s
                        if math.isfinite(core.sched.interval_s) else None),
            retention_s=(core.sched.retention_s
                         if math.isfinite(core.sched.retention_s) else None))
    return mtr.build_report(core, decisions,
                            conflict_stall_s=conflict_stall_s,
                            timing="timeline", timeline=summary)


def stage_timeline(arm: Arm, ctx: SimContext) -> None:
    """The pipeline's ``memory`` stage under ``timing="timeline"``:
    trace-driven replay with event-interleaved timing."""
    cfg = arm.system
    if not cfg.use_controller:
        return
    mem_cfg, retention, policy = memory_config(cfg)
    ctx.mem_cfg = mem_cfg
    ctx.controller = replay_timeline(
        ctx.events, mem_cfg, op_schedule=ctx.op_schedule,
        temp_c=cfg.temp_c, duration_s=ctx.duration_s,
        refresh_policy=policy, alloc_policy=cfg.alloc_policy,
        freq_hz=ctx.freq_hz or cfg.freq_hz, sample_scale=ctx.batch,
        retention_s=retention, granularity=cfg.refresh_granularity,
        reads_restore=cfg.reads_restore,
        recorder=ctx.recorder, backend=cfg.replay_backend,
        tiers=cfg.tiers)


TIMELINE_PIPELINE = DEFAULT_PIPELINE.with_stage("memory", stage_timeline)
