"""The staged simulation pipeline behind ``sim.run``.

Every arm flows through the same five stages::

    schedule  — resolve blocks and build the iteration's op stream
                (reversible pattern or whole-iteration activation
                buffering); ops carry *work*, not durations
    cost      — resolve the arm's cost model (``repro.sim.cost``) into an
                operating point and time the op stream: work → seconds at
                the point's clock, then simulate the timed schedule
    trace     — flatten the schedule onto one trace timeline; aggregate
                traffic, peak-live and lifetime numbers
    memory    — replay the trace through the bank-level ``repro.memory``
                controller (eDRAM banks, or the SRAM baseline's banks with
                an infinite retention floor and off-chip spills) at the
                cost model's clock; retention deadlines stay wall-clock
    energy    — systolic-array compute energy (scaled by the operating
                point's dynamic-energy factor), scalar cross-validation
                oracle, latency/TTA/ETA; assembles the ArmReport

Stages are pluggable: each is a ``(name, fn(arm, ctx))`` pair and
``Pipeline.with_stage`` / ``insert_after`` produce modified pipelines.
The closed-loop timeline model (``repro.sim.timeline``) is exactly such a
replacement: ``DEFAULT_PIPELINE.with_stage("memory", stage_timeline)`` —
selected by ``sim.run(arm, timing="timeline")``, the default.  The
additive model (``timing="additive"``) is this module's ``stage_memory``
and is kept bit-compatible as a cross-validation baseline.
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from concurrent.futures import ProcessPoolExecutor, as_completed
from typing import Callable, Optional, Sequence, Tuple

from repro.core import edram as ed
from repro.core import hwmodel as hw
from repro.core import schedule as sc
from repro.core.lifetime import array_throughput
from repro.memory import trace as mtr
from repro.sim.arm import Arm
from repro.sim.cost import FixedClock, cost_dict, op_timer, resolve_cost
from repro.sim.report import ArmReport

# the SRAM tier stores FP16 values; one value per word
SRAM_WORD_BITS = 16


@dataclasses.dataclass
class SimContext:
    """Mutable scratchpad threaded through the stages; custom stages read
    and write whichever fields they need."""
    blocks: tuple = ()
    bits: float = 0.0              # bits per value (BFP on eDRAM, FP16 else)
    specs: tuple = ()              # flattened OpSpecs (utilization inputs)
    cost: object = None            # resolved OperatingPoint (cost stage)
    freq_hz: float = 0.0           # the operating point's clock
    compute_scale: float = 1.0     # dynamic-energy multiplier on compute
    R: float = 0.0                 # effective MAC/s at the operating point
    batch: float = 1.0
    fwd: object = None             # SimResult (reversible pattern)
    bwd: object = None
    combined: object = None        # SimResult (irreversible single timeline)
    events: list = dataclasses.field(default_factory=list)
    op_durations: dict = dataclasses.field(default_factory=dict)
    # the merged op schedule [(name, start_s, end_s), ...] in execution
    # order — the timeline model walks this
    op_schedule: list = dataclasses.field(default_factory=list)
    duration_s: float = 0.0
    read_bits: float = 0.0
    write_bits: float = 0.0
    peak_live_bits: float = 0.0
    max_lifetime_s: float = 0.0    # per-sample data lifetime
    mem_cfg: object = None         # EDRAMConfig the controller replayed with
    controller: object = None      # ControllerReport (None on scalar path)
    report: object = None          # ArmReport (set by the energy stage)
    # optional repro.obs.SpanRecorder (sim.run(trace=...)); stages that
    # support it record spans/counters — observation only, never timing
    recorder: object = None
    # free-form scratch for custom stages (e.g. repro.serve stashes its
    # traffic/engine statistics here for its energy stage to read)
    extra: dict = dataclasses.field(default_factory=dict)


# ------------------------------------------------------------------ stages

def stage_schedule(arm: Arm, ctx: SimContext) -> None:
    """Resolve the workload: blocks, value width, utilization specs.
    Timing is deliberately absent — the ``cost`` stage owns work→seconds."""
    cfg = arm.system
    blocks = arm.resolve_blocks()
    ctx.blocks = blocks
    ctx.bits = hw.BFP_BITS if cfg.use_edram else hw.FP16_BITS
    ctx.specs = tuple(s for b in blocks for s in (b.f1, b.f2, b.g))
    ctx.batch = max(blocks[0].f1.batch, 1)


def stage_cost(arm: Arm, ctx: SimContext) -> None:
    """Resolve the arm's cost model into an operating point and time the
    op stream: every downstream second — op durations, bank-port service,
    refresh pulse widths — derives from this point's clock, while
    retention deadlines stay wall-clock (temperature-set)."""
    cfg = arm.system
    point = resolve_cost(arm.cost, cfg)
    ctx.cost = point
    ctx.freq_hz = point.freq_hz
    ctx.compute_scale = point.compute_scale
    ctx.R = array_throughput(cfg.array, point.freq_hz, list(ctx.specs),
                             cfg.bfp_group)
    seconds = op_timer(point, ctx.R)
    if arm.reversible:
        ctx.fwd, ctx.bwd = sc.simulate_training_iteration(
            ctx.blocks, ctx.R, ctx.bits, op_seconds=seconds)
    else:
        ctx.combined = sc.simulate_irreversible_iteration(
            ctx.blocks, ctx.R, ctx.bits, op_seconds=seconds)


def stage_trace(arm: Arm, ctx: SimContext) -> None:
    """One trace timeline + aggregate traffic/lifetime numbers."""
    if arm.reversible:
        ctx.events, ctx.op_durations, ctx.duration_s = mtr.merge_traces(
            ctx.fwd, ctx.bwd)
        off = ctx.fwd.total_time
        ctx.op_schedule = list(ctx.fwd.schedule) + [
            (name, start + off, end + off)
            for name, start, end in ctx.bwd.schedule]
        ctx.read_bits = ctx.fwd.read_bits + ctx.bwd.read_bits
        ctx.write_bits = ctx.fwd.write_bits + ctx.bwd.write_bits
        ctx.peak_live_bits = max(ctx.fwd.peak_live_bits,
                                 ctx.bwd.peak_live_bits)
        # weight-stationary streaming: per-sample producer→consumer window
        ctx.max_lifetime_s = max(ctx.fwd.max_lifetime,
                                 ctx.bwd.max_lifetime) / ctx.batch
        return
    sim = ctx.combined
    ctx.events = list(sim.trace)
    ctx.op_durations = {name: end - start
                        for name, start, end in sim.schedule}
    ctx.op_schedule = list(sim.schedule)
    ctx.duration_s = sim.total_time
    ctx.read_bits = sim.read_bits
    ctx.write_bits = sim.write_bits
    ctx.peak_live_bits = sim.peak_live_bits
    # whole-iteration buffers hold every sample, so their residency IS the
    # data lifetime; transients stream per sample
    buffered = {e.tensor for e in sim.trace if e.buffered}
    life = [(t, d) for t, d in sim.lifetimes.items()]
    ctx.max_lifetime_s = max(
        [d if t in buffered else d / ctx.batch for t, d in life],
        default=0.0)


def _sram_mem_config(cfg: hw.SystemConfig) -> ed.EDRAMConfig:
    """The SRAM baseline's on-chip tier as controller geometry: the same
    bank/word machinery, SRAM access energies, no refresh."""
    return dataclasses.replace(
        cfg.edram,
        word_bits=SRAM_WORD_BITS,
        n_banks=cfg.sram_banks,
        bank_kb=cfg.onchip_bits / 8.0 / 1024.0 / cfg.sram_banks,
        read_pj_per_bit=cfg.edram.sram_read_pj_per_bit,
        write_pj_per_bit=cfg.edram.sram_write_pj_per_bit)


def memory_config(cfg: hw.SystemConfig):
    """The controller-replay parameters an arm's system implies:
    ``(mem_cfg, retention_s, refresh_policy)``.  eDRAM arms replay their
    own geometry; the SRAM baseline replays the same bank machinery with
    an infinite retention floor and refresh disabled.  Tiered arms
    (``cfg.tiers``) carry their geometry and retention floors on the
    ``TierSpec``s themselves — the eDRAM config only supplies the
    off-chip energy and the per-tier defaults."""
    if cfg.tiers:
        return cfg.edram, None, cfg.refresh_policy
    if cfg.use_edram:
        return cfg.edram, None, cfg.refresh_policy
    # SRAM holds data indefinitely: infinite retention, never refresh
    return _sram_mem_config(cfg), math.inf, "none"


def stage_memory(arm: Arm, ctx: SimContext) -> None:
    """Trace-driven replay through the bank-level controller (additive
    stall model; the timeline model's stage lives in
    ``repro.sim.timeline``)."""
    cfg = arm.system
    if not cfg.use_controller:
        return
    mem_cfg, retention, policy = memory_config(cfg)
    ctx.mem_cfg = mem_cfg
    ctx.controller = mtr.replay(
        ctx.events, mem_cfg, temp_c=cfg.temp_c, duration_s=ctx.duration_s,
        refresh_policy=policy, alloc_policy=cfg.alloc_policy,
        freq_hz=ctx.freq_hz or cfg.freq_hz, sample_scale=ctx.batch,
        op_durations=ctx.op_durations, retention_s=retention,
        granularity=cfg.refresh_granularity,
        reads_restore=cfg.reads_restore, recorder=ctx.recorder,
        backend=cfg.replay_backend, tiers=cfg.tiers)


def _buffered_partition(events) -> tuple[float, list]:
    """Peak live bits of the streamed transients, and the whole-iteration
    buffers as (tensor, bits) in first-write order."""
    live: dict = {}
    peak = 0.0
    saves: list = []
    seen: set = set()
    for ev in events:
        if ev.buffered:
            if ev.kind in ("alloc", "write") and ev.tensor not in seen:
                seen.add(ev.tensor)
                saves.append((ev.tensor, ev.bits))
            continue
        if ev.kind in ("alloc", "write"):
            live[ev.tensor] = ev.bits
            peak = max(peak, sum(live.values()))
        elif ev.kind == "free":
            live.pop(ev.tensor, None)
    return peak, saves


def _scalar_memory(arm: Arm, ctx: SimContext):
    """The closed-form cross-validation oracle: per-sample streamed
    transients on-chip, whole-iteration buffers held greedily until
    capacity runs out, one store + one load per spilled buffer.

    When even the per-sample transients overflow on-chip capacity, the
    proportional overflow term below moves the overflowing share of the
    streamed traffic off-chip — a first-order model of the controller's
    per-tensor spills (it has no placement order), so ``oracle_rel_err``
    stays a useful cross-check instead of growing with the overflow
    (the PR 2 carried-over debt).  On the pinned workloads the streamed
    set fits and the term is exactly zero.

    Returns ``(MemoryEnergy, offchip_bits, refresh_free)``.
    """
    cfg = arm.system
    transient_peak, saves = _buffered_partition(ctx.events)
    stream_bits = transient_peak / ctx.batch
    budget = cfg.onchip_bits - stream_bits
    held = spilled = 0.0
    for _, bits in saves:
        if held + bits <= budget:
            held += bits
        else:
            spilled += bits
    offchip_bits = 2.0 * spilled          # store once, load once
    # a spilled buffer's store/load traffic moves off-chip, not on-chip
    read_bits = ctx.read_bits - spilled
    write_bits = ctx.write_bits - spilled
    overflow = max(0.0, stream_bits - cfg.onchip_bits)
    if overflow > 0.0:
        # streamed transients themselves overflow capacity: the
        # overflowing fraction of the streamed working set forces the
        # same fraction of the remaining on-chip traffic through DRAM
        frac = overflow / stream_bits
        off_r, off_w = read_bits * frac, write_bits * frac
        offchip_bits += off_r + off_w
        read_bits -= off_r
        write_bits -= off_w
    if cfg.use_edram:
        rf = ed.refresh_free(ctx.max_lifetime_s, cfg.temp_c)
        mem = ed.edram_energy(cfg.edram, read_bits, write_bits,
                              ctx.peak_live_bits, ctx.duration_s,
                              cfg.temp_c, needs_refresh=not rf)
        if offchip_bits:
            mem = dataclasses.replace(
                mem, offchip_j=offchip_bits * cfg.edram.dram_pj_per_bit
                * 1e-12)
        return mem, offchip_bits, rf
    mem = ed.sram_energy(cfg.edram, read_bits, write_bits, offchip_bits)
    return mem, offchip_bits, True


def stage_energy(arm: Arm, ctx: SimContext) -> None:
    """Compute energy + latency accounting; assembles the ArmReport."""
    cfg = arm.system
    blocks = ctx.blocks
    # gradient ops (U1a/U1w/U2a/U2w); the reversible arm also pays the
    # eq-2 input recompute (the paper's accepted overhead, §III)
    macs = sum(s.macs for s in ctx.specs) + sum(
        b.f1.macs_out * 2 + b.f2.macs_out * 2 for b in blocks)
    if arm.reversible:
        macs += sum(b.f1.macs_out + b.f2.macs_out for b in blocks)
    # dynamic compute energy at the operating point (∝ V², ×1.0 fixed)
    compute_j = macs * (cfg.mac_pj if cfg.use_edram
                        else cfg.mac_pj_fp16) * 1e-12 * ctx.compute_scale

    scalar_mem, scalar_offchip, rf_scalar = _scalar_memory(arm, ctx)
    ctrl = ctx.controller
    if ctrl is not None:
        memory_j = ctrl.energy.total_j
        stall_s = ctrl.stall_s
        offchip_bits = ctrl.offchip_bits
        # the bank-level verdict: refresh-free iff no bank refreshed and no
        # over-retention bank was left unrefreshed (data loss)
        rf = ((not any(b.refreshed for b in ctrl.banks)) and ctrl.safe
              if cfg.use_edram else True)
    else:
        memory_j = scalar_mem.total_j
        stall_s = 0.0
        offchip_bits = scalar_offchip
        rf = rf_scalar if cfg.use_edram else True

    latency_s = ctx.duration_s + stall_s + (
        offchip_bits / cfg.offchip_bw_bps if offchip_bits else 0.0)
    # leakage burns on the whole on-chip tier for the iteration's
    # wall-clock duration — the term that stops slow DVFS points from
    # looking free on energy (opt-in: see SystemConfig.charge_leakage)
    leakage_j = 0.0
    if cfg.charge_leakage:
        if cfg.tiers:
            # each tier leaks at its own cell's rate over its own
            # capacity (the SRAM share is what the iso-area sweep pays)
            leakage_j = sum(t.leakage_mw * 1e-3 * latency_s
                            for t in cfg.tiers)
        else:
            mw_per_kb = (cfg.edram.leakage_mw_per_kb if cfg.use_edram
                         else cfg.edram.sram_leakage_mw_per_kb)
            leakage_j = mw_per_kb * 1e-3 \
                * (cfg.onchip_bits / 8.0 / 1024.0) * latency_s
    energy_j = compute_j + memory_j + leakage_j
    rel_err = (abs(memory_j - scalar_mem.total_j) / scalar_mem.total_j
               if scalar_mem.total_j > 0 else 0.0)
    iters = arm.iters_to_target
    if ctx.recorder is not None:
        ctx.recorder.meta.setdefault("arm", arm.name)
        ctx.recorder.counter("compute_j", latency_s, compute_j)
        ctx.recorder.counter("leakage_j", latency_s, leakage_j)
        ctx.recorder.counter("energy_j", latency_s, energy_j)
    ctx.report = ArmReport(
        arm=arm.name,
        reversible=arm.reversible,
        latency_s=latency_s,
        energy_j=energy_j,
        compute_j=compute_j,
        memory_j=memory_j,
        scalar_memory_j=scalar_mem.total_j,
        oracle_rel_err=rel_err,
        stall_s=stall_s,
        max_lifetime_s=ctx.max_lifetime_s,
        refresh_free=rf,
        peak_live_bits=ctx.peak_live_bits,
        offchip_bits=offchip_bits,
        iters_to_target=iters,
        tta_s=latency_s * iters if iters else None,
        eta_j=energy_j * iters if iters else None,
        timing=ctrl.timing if ctrl is not None else "scalar",
        refresh_stall_s=ctrl.refresh_stall_s if ctrl is not None else 0.0,
        refresh_hidden_j=ctrl.refresh_hidden_j if ctrl is not None else 0.0,
        leakage_j=leakage_j,
        rows_refreshed=ctrl.rows_refreshed if ctrl is not None else 0,
        row_hidden_frac=ctrl.row_hidden_frac if ctrl is not None else 0.0,
        freq_hz=ctx.freq_hz or cfg.freq_hz,
        pulse_exceeds_retention=(ctrl.pulse_exceeds_retention
                                 if ctrl is not None else False),
        timeline=(dict(ctrl.timeline)
                  if ctrl is not None and ctrl.timeline else {}),
        tiers=(tuple(dict(t) for t in ctrl.tiers)
               if ctrl is not None and ctrl.tiers else ()),
        config=_config_dict(arm),
        memory=_memory_dict(ctrl),
        controller=ctrl,
        trace=ctx.recorder,
    )


def _config_dict(arm: Arm) -> dict:
    """The fully resolved arm as a JSON-safe dict."""
    system = dataclasses.asdict(arm.system)
    if system.get("tiers"):
        # asdict keeps the TierSpec tuple a tuple; JSON reads it back as
        # a list, so serialize it as one for a lossless round trip
        system["tiers"] = [dict(t) for t in system["tiers"]]
    return {
        "name": arm.name,
        "reversible": arm.reversible,
        "iters_to_target": arm.iters_to_target,
        "cost": cost_dict(arm.cost),
        "system": system,
        "workload": (dataclasses.asdict(arm.workload)
                     if arm.workload is not None and arm.blocks is None
                     else None),
        "blocks": ([dataclasses.asdict(b) for b in arm.blocks]
                   if arm.blocks is not None else None),
    }


def _memory_dict(ctrl) -> dict:
    """ControllerReport as a JSON-safe dict (empty-ish on the scalar path)."""
    if ctrl is None:
        return {"mode": "scalar", "banks": [], "spilled": []}
    out = {
        "mode": "controller",
        "timing": ctrl.timing,
        "refresh_policy": ctrl.refresh_policy,
        "granularity": ctrl.granularity,
        "rows_refreshed": ctrl.rows_refreshed,
        "row_hidden_frac": ctrl.row_hidden_frac,
        "alloc_policy": ctrl.alloc_policy,
        "temp_c": ctrl.temp_c,
        "duration_s": ctrl.duration_s,
        # strict-JSON safety: math.inf (SRAM's never-refresh floor) is not
        # representable in plain JSON, so it serializes as null
        "retention_s": (ctrl.retention_s
                        if math.isfinite(ctrl.retention_s) else None),
        "interval_s": (ctrl.interval_s
                       if math.isfinite(ctrl.interval_s) else None),
        "pulse_exceeds_retention": ctrl.pulse_exceeds_retention,
        "read_j": ctrl.read_j,
        "restore_j": ctrl.restore_j,
        "write_j": ctrl.write_j,
        "refresh_j": ctrl.refresh_j,
        "refresh_read_j": ctrl.refresh_read_j,
        "refresh_restore_j": ctrl.refresh_restore_j,
        "refresh_hidden_j": ctrl.refresh_hidden_j,
        "offchip_j": ctrl.offchip_j,
        "stall_s": ctrl.stall_s,
        "conflict_stall_s": ctrl.conflict_stall_s,
        "refresh_stall_s": ctrl.refresh_stall_s,
        "spill_bits": ctrl.spill_bits,
        "offchip_bits": ctrl.offchip_bits,
        "refresh_count": ctrl.refresh_count,
        "safe": ctrl.safe,
        "spilled": list(ctrl.spilled_tensors),
        "evicted": list(ctrl.evicted_tensors),
        "timeline": dict(ctrl.timeline) if ctrl.timeline else None,
        "banks": [dataclasses.asdict(b) for b in ctrl.banks],
    }
    # only hybrid replays carry tiers; omitted otherwise so the classic
    # reports' serialized shape (and their golden pins) stays unchanged
    if ctrl.tiers:
        out["tiers"] = [dict(t) for t in ctrl.tiers]
    return out


# ---------------------------------------------------------------- pipeline

Stage = Tuple[str, Callable[[Arm, SimContext], None]]

DEFAULT_STAGES: Tuple[Stage, ...] = (
    ("schedule", stage_schedule),
    ("cost", stage_cost),
    ("trace", stage_trace),
    ("memory", stage_memory),
    ("energy", stage_energy),
)


@dataclasses.dataclass(frozen=True)
class Pipeline:
    """An ordered tuple of named stages; immutable — the ``with_*``
    helpers return modified copies."""
    stages: Tuple[Stage, ...] = DEFAULT_STAGES

    def stage_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.stages)

    def _require(self, name: str) -> None:
        if name not in self.stage_names():
            raise KeyError(f"no stage {name!r}; have "
                           f"{', '.join(self.stage_names())}")

    def with_stage(self, name: str, fn: Callable) -> "Pipeline":
        """Replace stage ``name`` with ``fn(arm, ctx)``.

        Args:
            name: an existing stage name (``schedule`` / ``cost`` /
                ``trace`` / ``memory`` / ``energy`` on the default
                pipeline); ``KeyError`` if absent.
            fn: callable ``(arm: Arm, ctx: SimContext) -> None`` that
                mutates ``ctx`` in place — e.g. set ``ctx.controller`` to
                a custom ``ControllerReport`` (this is how the timeline
                model replaces the ``memory`` stage).

        Returns:
            A new ``Pipeline``; ``self`` is unchanged (frozen).
        """
        self._require(name)
        return Pipeline(tuple((n, fn if n == name else f)
                              for n, f in self.stages))

    def insert_after(self, name: str, new_name: str,
                     fn: Callable) -> "Pipeline":
        """Insert stage ``new_name`` (same ``fn(arm, ctx)`` contract as
        :meth:`with_stage`) right after ``name`` — e.g. a post-processor
        that rewrites the controller report before energy accounting.
        Returns a new ``Pipeline``; ``self`` is unchanged."""
        self._require(name)
        out: list = []
        for n, f in self.stages:
            out.append((n, f))
            if n == name:
                out.append((new_name, fn))
        return Pipeline(tuple(out))

    def run(self, arm: Arm, *, recorder=None, profile: bool = False) -> tuple:
        """Run all stages; returns ``(ArmReport, SimContext)``.

        ``recorder`` (a ``repro.obs.SpanRecorder``) is threaded to every
        stage via ``ctx.recorder`` and ends up on ``report.trace``;
        ``profile=True`` wall-clocks each stage (``time.perf_counter``)
        into ``report.profile`` — both are pure observation, so every
        report scalar is bit-identical either way."""
        ctx = SimContext()
        ctx.recorder = recorder
        stages_s: dict = {}
        for name, fn in self.stages:
            if profile:
                t0 = time.perf_counter()
                fn(arm, ctx)
                stages_s[name] = time.perf_counter() - t0
            else:
                fn(arm, ctx)
        if profile and ctx.report is not None:
            ctx.report = dataclasses.replace(
                ctx.report,
                profile={"stages": stages_s,
                         "total_s": sum(stages_s.values())})
        return ctx.report, ctx


DEFAULT_PIPELINE = Pipeline()

# stall-model names sim.run/sweep resolve; "timeline" is the default
TIMINGS = ("additive", "timeline")
DEFAULT_TIMING = "timeline"


def resolve_pipeline(timing: Optional[str] = None,
                     pipeline: Optional[Pipeline] = None) -> Pipeline:
    """The pipeline a ``timing`` name selects: ``"additive"`` is
    :data:`DEFAULT_PIPELINE`, ``"timeline"`` swaps in the closed-loop
    memory stage.  An explicit ``pipeline`` wins and excludes
    ``timing``."""
    if pipeline is not None:
        if timing is not None:
            raise ValueError("pass either pipeline= or timing=, not both")
        return pipeline
    timing = DEFAULT_TIMING if timing is None else timing
    if timing == "additive":
        return DEFAULT_PIPELINE
    if timing == "timeline":
        from repro.sim.timeline import TIMELINE_PIPELINE
        return TIMELINE_PIPELINE
    raise ValueError(f"unknown timing {timing!r}; choose from {TIMINGS}")


def run(arm: Arm, pipeline: Optional[Pipeline] = None, *,
        timing: Optional[str] = None, trace=None,
        profile: bool = False) -> ArmReport:
    """Simulate one arm through the staged pipeline.

    Args:
        arm: the declarative :class:`~repro.sim.arm.Arm` (workload +
            ``SystemConfig`` + memory policies).
        pipeline: explicit stage list; mutually exclusive with
            ``timing``.
        timing: stall-model selector — ``"timeline"`` (default; the
            closed-loop event-interleaved model where refresh hides in
            bank-idle windows) or ``"additive"`` (per-op overshoot and
            per-pulse serialization summed; the PR-2-compatible
            cross-validation baseline).
        trace: flight-recorder opt-in — ``True`` allocates a fresh
            ``repro.obs.SpanRecorder``, or pass your own; it records
            typed spans (op/port/refresh/spill) and counter series as
            the engine runs and lands on ``report.trace`` (export with
            ``repro.obs.export_chrome_trace``, check with
            ``repro.obs.reconcile``).  Pure observation: with or
            without it, every report number is bit-identical.
        profile: wall-clock each pipeline stage into
            ``report.profile["stages"]`` (also observation-only).

    Returns:
        An :class:`~repro.sim.report.ArmReport` — latency/energy in
        s/J, the controller's per-bank breakdown under ``.memory``, and
        (timeline model) ``refresh_stall_s`` / ``refresh_hidden_j`` plus
        the ``.timeline`` makespan summary.
    """
    recorder = trace
    if trace is True:
        from repro.obs.recorder import SpanRecorder
        recorder = SpanRecorder()
    # an arm that owns a pipeline family (e.g. the repro.serve arms, whose
    # schedule/trace/energy stages are serving-specific) maps the timing
    # name to its own Pipeline; an explicit pipeline= still wins
    if pipeline is None and hasattr(arm, "select_pipeline"):
        pipe = arm.select_pipeline(
            DEFAULT_TIMING if timing is None else timing)
    else:
        pipe = resolve_pipeline(timing, pipeline)
    report, _ = pipe.run(arm, recorder=recorder, profile=profile)
    return report


def _with_freq(arm: Arm, f) -> Arm:
    """One frequency-axis grid point: a number pins a ``FixedClock`` at
    that many Hz; a cost model (anything with ``resolve``) is installed
    as-is — e.g. a ``DVFSState`` for voltage-scaled points."""
    if hasattr(f, "resolve"):
        return arm.with_cost(f)
    return arm.with_cost(FixedClock(freq_hz=float(f)))


def _with_split(arm: Arm, s) -> Arm:
    """One iso-area-split grid point: replace the arm's memory with the
    hybrid SRAM+eDRAM tiering at SRAM area share ``s`` (see
    ``repro.memory.tiers.iso_area_tiers``) under the ``lifetime_tiered``
    routing policy.  ``onchip_bits`` tracks the tiers' total capacity so
    the scalar oracle sees the same budget the controller enforces."""
    from repro.memory.tiers import iso_area_tiers
    tiers = iso_area_tiers(arm.system.edram, float(s),
                           sram_banks=arm.system.sram_banks)
    return arm.with_system(
        tiers=tiers, alloc_policy="lifetime_tiered", use_edram=True,
        onchip_bits=sum(t.capacity_bits for t in tiers))


def _expand_grid(arms: Sequence[Arm], workloads, temps, freqs,
                 splits=None) -> list:
    """``arms × workloads × temps × freqs × splits`` as concrete arms,
    in deterministic (arms-outer, splits-inner) order."""
    out = []
    for arm in arms:
        for wl in (workloads if workloads is not None else (None,)):
            if wl is None:
                a = arm
            elif isinstance(wl, dict):
                a = arm.with_workload(**wl)
            else:                       # a WorkloadSpec replaces wholesale
                a = dataclasses.replace(arm, workload=wl, blocks=None)
            for t in (temps if temps is not None else (None,)):
                at = a if t is None else a.with_system(temp_c=t)
                for f in (freqs if freqs is not None else (None,)):
                    af = at if f is None else _with_freq(at, f)
                    for s in (splits if splits is not None else (None,)):
                        out.append(af if s is None else _with_split(af, s))
    return out


def _sweep_one(job: tuple) -> ArmReport:
    """Process-pool worker: simulate one (arm, timing, pipeline, profile)
    job.  Top-level so it pickles by reference."""
    arm, timing, pipeline, profile = job
    return run(arm, pipeline, timing=timing, profile=profile)


def sweep(arms: Sequence[Arm], pipeline: Optional[Pipeline] = None, *,
          timing: Optional[str] = None,
          workloads: Optional[Sequence] = None,
          temps: Optional[Sequence[float]] = None,
          freqs: Optional[Sequence] = None,
          splits: Optional[Sequence[float]] = None,
          parallel=None, profile: bool = False,
          progress=None) -> list:
    """Simulate a grid of arms; one :class:`ArmReport` per grid point.

    Args:
        arms: the arms to sweep.
        pipeline: explicit stage list (mutually exclusive with
            ``timing``); must be picklable (module-level stage
            functions) when ``parallel`` is used.
        timing: stall-model selector, as in :func:`run`.
        workloads: optional workload axis — each entry is either a
            ``WorkloadSpec`` (replaces the arm's workload) or a dict of
            ``WorkloadSpec`` field overrides (``with_workload``).
        temps: optional die-temperature axis (°C, ``with_system``).
        freqs: optional operating-point axis — each entry is a frequency
            in Hz (installs ``FixedClock(freq_hz=...)``) or a cost model
            (e.g. ``DVFSState``; installed via ``Arm.with_cost``).
            Retention deadlines stay wall-clock, so refresh hiding and
            the refresh-free verdict move across this axis.
        splits: optional iso-area SRAM:eDRAM capacity-split axis — each
            entry is an SRAM area share in [0, 1]; the grid point
            replaces the arm's memory with the hybrid tiering from
            ``repro.memory.tiers.iso_area_tiers`` under the
            ``lifetime_tiered`` routing policy (``0.0`` is the stock
            all-eDRAM array, ``1.0`` the all-SRAM iso-area equivalent).
        parallel: ``None``/``0``/``1`` → sequential; ``True`` → one
            worker per CPU; an int → that many process-pool workers.
        profile: wall-clock each grid point's stages into its report's
            ``profile`` field (aggregate across the grid with
            ``repro.obs.aggregate_profiles``).
        progress: per-completion visibility for long grids — ``True``
            emits a ``repro.obs.log`` info line per finished point
            (grid index, arm, elapsed seconds) to stderr regardless of
            the ``REPRO_LOG`` threshold (you asked for it), or pass a
            callable ``progress(i, arm_name, elapsed_s)``.  Completion
            order, not grid order; the returned list stays in grid
            order.

    Returns:
        Reports in deterministic grid order — ``arms`` outermost, then
        ``workloads``, then ``temps``, then ``freqs``, then ``splits``
        — identical regardless of ``parallel`` (results are collected
        in submission order).
    """
    resolve_pipeline(timing, pipeline)      # validate eagerly
    grid = _expand_grid(arms, workloads, temps, freqs, splits)
    jobs = [(a, timing, pipeline, profile) for a in grid]
    if progress is True:
        from repro.obs import log as _obslog
        progress = (lambda i, name, dt:
                    _obslog.log("info", "sweep_point", force=True,
                                index=i, arm=name, elapsed_s=dt))
    t0 = time.perf_counter()
    workers = (os.cpu_count() or 1) if parallel is True else int(parallel or 0)
    if workers > 1 and len(jobs) > 1:
        with ProcessPoolExecutor(max_workers=min(workers, len(jobs))) as ex:
            if progress is None:
                return list(ex.map(_sweep_one, jobs))
            futs = {ex.submit(_sweep_one, j): i for i, j in enumerate(jobs)}
            for fut in as_completed(futs):
                i = futs[fut]
                progress(i, grid[i].name, time.perf_counter() - t0)
            return [fut.result() for fut in futs]  # dicts keep insert order
    out = []
    for i, j in enumerate(jobs):
        out.append(_sweep_one(j))
        if progress is not None:
            progress(i, grid[i].name, time.perf_counter() - t0)
    return out
