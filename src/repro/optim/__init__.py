from repro.optim.optimizers import (AdamWConfig, OptConfig, SGDConfig,
                                    global_norm, opt_init, opt_update)
from repro.optim.schedule import (constant, cosine_warmup, step_decay)

__all__ = ["AdamWConfig", "OptConfig", "SGDConfig", "global_norm",
           "opt_init", "opt_update", "constant", "cosine_warmup",
           "step_decay"]
