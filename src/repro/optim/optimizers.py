"""Optimizers (pure pytree-functional; no optax in this environment).

SGD+momentum is the paper's optimizer (§VI-B: momentum 0.9, weight decay
5e-4); AdamW is provided for the LM examples.  All states are fp32 master
copies — the mixed-precision policy keeps compute in bf16 while updates
happen in fp32 (hybrid persistent/transient storage, DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Union

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    momentum: float = 0.9
    weight_decay: float = 5e-4
    nesterov: bool = False
    clip_norm: float | None = 1.0


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0


OptConfig = Union[SGDConfig, AdamWConfig]


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def opt_init(cfg: OptConfig, params):
    zeros = lambda: jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    if isinstance(cfg, SGDConfig):
        return {"mu": zeros()}
    return {"mu": zeros(), "nu": zeros()}


def opt_update(cfg: OptConfig, grads, state, params, lr):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm is not None:
        grads, gnorm = _clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)

    if isinstance(cfg, SGDConfig):
        mu = jax.tree_util.tree_map(
            lambda m, g: cfg.momentum * m + g, state["mu"], grads)
        upd = jax.tree_util.tree_map(
            lambda m, g: cfg.momentum * m + g, mu, grads) if cfg.nesterov \
            else mu
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p - lr * (u + cfg.weight_decay * p)).astype(p.dtype),
            params, upd)
        return new_params, {"mu": mu}, {"grad_norm": gnorm}

    # AdamW (bias-corrected via step count carried in the state)
    step = state.get("step", jnp.zeros((), jnp.int32)) + 1
    mu = jax.tree_util.tree_map(
        lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state["mu"], grads)
    nu = jax.tree_util.tree_map(
        lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state["nu"], grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    new_params = jax.tree_util.tree_map(
        lambda p, m, v: (p - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
                                   + cfg.weight_decay * p)).astype(p.dtype),
        params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, {"grad_norm": gnorm}
