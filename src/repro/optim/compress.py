"""Gradient compression for the thin inter-pod links (DESIGN.md §6).

int8 block-quantized all-reduce with error feedback: gradients are scaled
per block, quantized to int8, summed in int32 (no overflow up to 2²³
participants), and dequantized; the quantization residual is carried to the
next step (error feedback keeps SGD/Adam convergence — Karimireddy et al.).

Used inside ``shard_map`` over the gradient-reduction axes; ~4× less DP
traffic than fp32 (2× vs bf16) where the network is thinnest.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.utils import ceil_to


def _quantize_int8(x: jax.Array, block: int = 2048):
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    npad = ceil_to(n, block)
    flat = jnp.pad(flat, (0, npad - n)).reshape(-1, block)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale, n


def _dequantize(q: jax.Array, scale: jax.Array, n: int, shape):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(shape)


def compressed_psum(x: jax.Array, axis_name, block: int = 2048) -> jax.Array:
    """int8-quantized psum-mean over ``axis_name``.

    Each participant contributes q_i·scale_i; the sum is reconstructed with
    the mean scale (exact when scales agree; the residual is absorbed by
    error feedback at the caller).
    """
    q, scale, n = _quantize_int8(x, block)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)     # int32: no overflow
    mean_scale = jax.lax.pmean(scale, axis_name)
    nproc = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    summed = qsum.astype(jnp.float32) * mean_scale          # [blocks, block]
    return summed.reshape(-1)[:n].reshape(x.shape) / nproc


def compress_decompress(x: jax.Array, block: int = 2048) -> jax.Array:
    """Local quantize→dequantize round trip (what each peer receives)."""
    q, scale, n = _quantize_int8(x, block)
    return _dequantize(q, scale, n, x.shape)


def error_feedback_update(grads, residuals, block: int = 2048):
    """Returns (compressed grads + carried residual, new residuals)."""
    def one(g, r):
        g = g.astype(jnp.float32) + r
        sent = compress_decompress(g, block)
        return sent, g - sent

    out = jax.tree_util.tree_map(one, grads, residuals)
    sent = jax.tree_util.tree_map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree_util.tree_map(lambda t: t[1], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
    return sent, res
