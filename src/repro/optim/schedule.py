"""Learning-rate schedules (step functions: step int32 → lr f32)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)


def step_decay(lr: float, boundaries: tuple[int, ...], factor: float = 0.1):
    """The paper's schedule: divide by 10 at epochs 30/60 (§VI-B)."""
    def fn(step):
        mult = jnp.ones((), jnp.float32)
        for b in boundaries:
            mult = jnp.where(step >= b, mult * factor, mult)
        return lr * mult
    return fn


def cosine_warmup(lr: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, lr * cos)
    return fn
