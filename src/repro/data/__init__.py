"""repro.data"""
