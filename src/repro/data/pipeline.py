"""Data pipeline: deterministic synthetic LM stream + byte-level file
corpus, per-host sharding, background prefetch.

Determinism contract: batch ``i`` of host ``h`` depends only on
``(seed, i, h)`` — after a restart at step N the pipeline resumes exactly at
batch N (fault tolerance: data and model state recover together).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch_per_host: int
    seed: int = 0
    kind: str = "synthetic"      # synthetic | bytes
    path: Optional[str] = None   # for kind="bytes"
    zipf_a: float = 1.2          # synthetic token distribution


class SyntheticLM:
    """Zipf-distributed token stream with a learnable bigram structure
    (next token correlates with current), so losses actually decrease."""

    def __init__(self, cfg: DataConfig, host_id: int = 0):
        self.cfg = cfg
        self.host = host_id

    def batch(self, index: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, self.host, index]))
        b, s = cfg.batch_per_host, cfg.seq_len
        base = rng.zipf(cfg.zipf_a, size=(b, s + 1)) % cfg.vocab
        # inject bigram structure: token[t+1] == f(token[t]) half the time
        follow = (base[:, :-1] * 31 + 7) % cfg.vocab
        coin = rng.random((b, s)) < 0.5
        seq = base[:, 1:].copy()
        seq[coin] = follow[coin]
        tokens = np.concatenate([base[:, :1], seq], axis=1).astype(np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


class ByteCorpus:
    """Byte-level LM over a local file (vocab 256), sequential windows
    per host with stride striping across hosts."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.path, "ByteCorpus needs cfg.path"
        self.data = np.frombuffer(Path(cfg.path).read_bytes(), dtype=np.uint8)
        self.cfg = cfg
        self.host = host_id
        self.n_hosts = n_hosts

    def batch(self, index: int) -> dict:
        cfg = self.cfg
        b, s = cfg.batch_per_host, cfg.seq_len
        n = len(self.data) - s - 1
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, self.host, index]))
        starts = rng.integers(0, n, size=b)
        toks = np.stack([self.data[st:st + s + 1] for st in starts])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def make_source(cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
    if cfg.kind == "synthetic":
        return SyntheticLM(cfg, host_id)
    if cfg.kind == "bytes":
        return ByteCorpus(cfg, host_id, n_hosts)
    raise ValueError(cfg.kind)


class Prefetcher:
    """Background-thread prefetch (decouples host data prep from steps)."""

    def __init__(self, source, start_index: int = 0, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._index = start_index
        self._source = source
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        i = self._index
        while not self._stop.is_set():
            try:
                self._q.put(self._source.batch(i), timeout=0.2)
                i += 1
            except queue.Full:
                continue

    def next(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
