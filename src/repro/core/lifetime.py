"""Data-lifetime closed forms for DuDNN training (CAMEL §IV, eqs 3–10).

Given per-layer op sizes and hardware throughput R (MAC/s), these compute
the maximum time any tensor must survive in eDRAM between its producing
write and its last read, under the paper's computation pattern
(Figs 12–15).  ``core.schedule`` cross-validates these closed forms with a
discrete-event simulation of the same pattern.

Latencies (eqs 3–5): T = N / R with N = B·C_in·W·H·k² MACs·(C_out folded
into R's utilization — the paper's formulation; we keep it verbatim).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One CONV/matmul op (paper's notation, eqs 3-5)."""
    batch: int
    c_in: int
    c_out: int
    width: int
    height: int
    kernel: int = 1

    @property
    def macs(self) -> float:
        return (self.batch * self.c_in * self.width * self.height
                * self.kernel ** 2)

    @property
    def macs_out(self) -> float:
        """Backward-pass size (eqs 7-8 use C_out in place of C_in)."""
        return (self.batch * self.c_out * self.width * self.height
                * self.kernel ** 2)


@dataclasses.dataclass(frozen=True)
class DuBlockSpec:
    """One DuDNN block: branch F1/F2 + backbone G (Fig 12a)."""
    f1: OpSpec
    f2: OpSpec
    g: OpSpec


def latency(n_macs: float, throughput: float) -> float:
    return n_macs / throughput


def forward_lifetimes(blocks: Sequence[DuBlockSpec], R: float) -> list[dict]:
    """Per-layer {y1, y2, y3} forward data lifetimes (eq 6 terms, Fig 13)."""
    L = len(blocks)
    tG = [latency(b.g.macs, R) for b in blocks]
    tF1 = [latency(b.f1.macs, R) for b in blocks]
    tF2 = [latency(b.f2.macs, R) for b in blocks]
    out = []
    for l in range(L):
        nxt = min(l + 1, L - 1)
        last = l == L - 1
        rec = {
            "y3": tG[l] + tF1[l] + tF2[l],
            # T_y1 = t5−t2 ; T_y2 = t5−t1 (paper Fig 13) — for the last layer
            # the consumer is the loss head, bounded by its own block time.
            "y1": tF1[l] + (0.0 if last else tG[nxt] + tF2[nxt]),
            "y2": tF1[l] + tF2[l] + (0.0 if last else tG[nxt] + tF2[nxt]),
        }
        out.append(rec)
    return out


def backward_lifetimes(blocks: Sequence[DuBlockSpec], R: float) -> list[dict]:
    """Per-layer {g1, g2, y1, y2} backward lifetimes (eq 9 terms, Fig 15).

    eqs 7-8: T_{U2a}=T_{U2w}=T_{F2}, T_{U1a}=T_{U1w}=T_{F1}, evaluated with
    output-channel sizes.
    """
    L = len(blocks)
    tF1 = [latency(b.f1.macs_out, R) for b in blocks]
    tF2 = [latency(b.f2.macs_out, R) for b in blocks]
    out = []
    for l in range(L):
        prv = max(l - 1, 0)
        first = l == 0
        rec = {
            # T_g1 = t9−t4 = U1a_l + U2w_{l−1} + U2a_{l−1} + F2_{l−1} + U1w_{l−1}
            "g1": tF1[l] + (0.0 if first
                            else 3 * tF2[prv] + tF1[prv]),
            # T_g2 = t4−t1 = U2a_l + F2_l + U1w_l
            "g2": 2 * tF2[l] + tF1[l],
            # T_y1 = T_y2 = t7−t2 = F2_l + U1w_l + U1a_l + U2w_{l−1} + U2a_{l−1}
            "y1": tF2[l] + 2 * tF1[l] + (0.0 if first else 2 * tF2[prv]),
        }
        rec["y2"] = rec["y1"]
        out.append(rec)
    return out


def max_data_lifetime(blocks: Sequence[DuBlockSpec], R: float) -> float:
    """eq 10: T_data = max(T_f, T_b)."""
    tf = max(max(d.values()) for d in forward_lifetimes(blocks, R))
    tb = max(max(d.values()) for d in backward_lifetimes(blocks, R))
    return max(tf, tb)


# --------------------------------------------------------------------------
# systolic-array throughput with utilization (Table III's sub-linearity)
# --------------------------------------------------------------------------

def array_throughput(array: int, freq_hz: float, specs: Sequence[OpSpec],
                     bfp_group: int = 3) -> float:
    """Effective MAC/s of an ``array×array`` systolic core at ``freq_hz``.

    Each cell multiplies a ``bfp_group²`` BFP group per cycle (§VI-D).  A
    layer whose dims don't fill the array wastes cells — utilization =
    useful MACs / (cells × occupied cycles), so doubling the array does NOT
    halve latency for small layers (paper Table III).
    """
    peak = array * array * freq_hz * bfp_group * bfp_group
    if not specs:
        return peak
    utils = []
    for s in specs:
        m = s.batch * s.width * s.height          # output rows
        n = max(s.c_out, 1)                       # output cols
        k = max(s.c_in * s.kernel ** 2, 1)
        tile = array * bfp_group
        cycles = -(-m // tile) * -(-n // tile) * k
        useful = m * n * k
        utils.append(useful / (cycles * tile * tile))
    return peak * (sum(utils) / len(utils))


def duplex_block_specs(n_blocks: int, batch: int, spatial: int,
                       c_branch: int, c_backbone: int,
                       kernel: int = 3) -> list[DuBlockSpec]:
    """Paper-style CNN DuDNN blocks (Branch-L + ResNet-style backbone).

    ``spatial`` is the pooled H=W fed to the branch (§III-C, 7×7 default).
    """
    f = OpSpec(batch=batch, c_in=c_branch, c_out=c_branch, width=spatial,
               height=spatial, kernel=kernel)
    g = OpSpec(batch=batch, c_in=c_backbone, c_out=c_backbone,
               width=spatial * 2, height=spatial * 2, kernel=kernel)
    return [DuBlockSpec(f1=f, f2=f, g=g) for _ in range(n_blocks)]


def lm_branch_block_specs(n_blocks: int, batch: int, pooled_seq: int,
                          d_branch: int, d_model: int) -> list[DuBlockSpec]:
    """Map the LM duplex branch (attention F1 + MLP F2, §III) onto OpSpecs:
    tokens = 1×pooled_seq 'spatial' positions, channels = widths."""
    f1 = OpSpec(batch=batch, c_in=d_branch, c_out=d_branch,
                width=pooled_seq, height=1, kernel=1)
    f2 = OpSpec(batch=batch, c_in=d_branch, c_out=4 * d_branch,
                width=pooled_seq, height=1, kernel=1)
    g = OpSpec(batch=batch, c_in=d_model, c_out=d_model,
               width=pooled_seq * 16, height=1, kernel=1)
    return [DuBlockSpec(f1=f1, f2=f2, g=g) for _ in range(n_blocks)]
