"""3T gain-cell eDRAM model (CAMEL §V-D, Fig 19/22).

Retention curve calibrated to the paper's Monte-Carlo endpoints (Fig 22):
worst-case retention 30 µs at −30 °C and 3.4 µs at +100 °C at VDD = 0.8 V,
0.5 write-bitline activity — an exponential in temperature (subthreshold
leakage I_SUB through the write transistor M1 dominates the storage-node
droop, and I_SUB is exponential in T).

Energy constants are *modeled* 16 nm numbers (the paper reports only
relative results); they are chosen so the reproduced Fig 24 ratios land in
the paper's reported bands (≥2–3× ETA saving) and are exposed as dataclass
fields so sensitivity studies can sweep them.
"""
from __future__ import annotations

import dataclasses
import math

# Fig 22 calibration endpoints (worst case across 1000 MC points, 99% yield)
_T_LO, _RET_LO = -30.0, 30e-6
_T_HI, _RET_HI = 100.0, 3.4e-6
_K = math.log(_RET_LO / _RET_HI) / (_T_HI - _T_LO)      # 1/°C
_A = _RET_HI * math.exp(_K * _T_HI)                     # seconds


@dataclasses.dataclass(frozen=True)
class EDRAMConfig:
    # storage geometry (§V-C/D): 58-bit words × 1024 rows per bank — matched
    # to the 2D BFP group (4-bit shared exp + 9 × 6-bit signed mantissas)
    word_bits: int = 58
    words_per_bank: int = 1024
    n_banks: int = 12
    bank_kb: float = 32.0

    # access energies, pJ/bit (modeled; eDRAM gain cell reads are cheaper
    # than 6T SRAM at iso-node, writes comparable)
    read_pj_per_bit: float = 0.013
    write_pj_per_bit: float = 0.017
    # a refresh pulse is a read (sense the droop) plus a restore (drive the
    # write bitline back to full level).  ``refresh_pj_per_bit`` is the
    # legacy aggregate; set the two split fields to model them separately
    # (sensitivity studies) — when only one is given the other is the
    # remainder of the aggregate, when neither is given the aggregate is
    # split 0.4/0.6 (read port vs the costlier write-back, mirroring the
    # read/write pJ ratio above).
    refresh_pj_per_bit: float = 0.020    # read + restore (aggregate)
    refresh_read_pj_per_bit: float | None = None
    refresh_restore_pj_per_bit: float | None = None
    leakage_mw_per_kb: float = 0.004     # no cross-coupled inverters

    # SRAM comparison points (6T, same node)
    sram_read_pj_per_bit: float = 0.024
    sram_write_pj_per_bit: float = 0.026
    sram_leakage_mw_per_kb: float = 0.013
    density_vs_sram: float = 2.0         # ≥2× (paper §I, [14])

    # off-chip DRAM (the SRAM-only baseline's second tier; LPDDR5-class —
    # see EXPERIMENTS.md for the sensitivity of the Fig 24 ratio to this)
    dram_pj_per_bit: float = 2.0

    @property
    def refresh_read_pj(self) -> float:
        """Resolved read-phase refresh energy (pJ/bit)."""
        if self.refresh_read_pj_per_bit is not None:
            return self.refresh_read_pj_per_bit
        if self.refresh_restore_pj_per_bit is not None:
            return max(0.0,
                       self.refresh_pj_per_bit - self.refresh_restore_pj_per_bit)
        return 0.4 * self.refresh_pj_per_bit

    @property
    def refresh_restore_pj(self) -> float:
        """Resolved restore-phase refresh energy (pJ/bit)."""
        if self.refresh_restore_pj_per_bit is not None:
            return self.refresh_restore_pj_per_bit
        if self.refresh_read_pj_per_bit is not None:
            return max(0.0,
                       self.refresh_pj_per_bit - self.refresh_read_pj_per_bit)
        return 0.6 * self.refresh_pj_per_bit

    @property
    def refresh_total_pj(self) -> float:
        """Read + restore pJ/bit; equals ``refresh_pj_per_bit`` unless the
        split fields override it."""
        return self.refresh_read_pj + self.refresh_restore_pj


def retention_s(temp_c: float) -> float:
    """Worst-case refresh-free retention time at ``temp_c`` (Fig 22)."""
    return _A * math.exp(-_K * temp_c)


def refresh_interval_s(temp_c: float, guard: float = 1.0) -> float:
    return retention_s(temp_c) / max(guard, 1e-9)


def refresh_free(data_lifetime_s: float, temp_c: float) -> bool:
    """The co-design criterion: T_data < retention (eq 10 vs Fig 22)."""
    return data_lifetime_s < retention_s(temp_c)


def refresh_margin(data_lifetime_s: float, temp_c: float) -> float:
    """retention / lifetime; > 1 means refresh-free with that headroom."""
    return retention_s(temp_c) / max(data_lifetime_s, 1e-30)


@dataclasses.dataclass(frozen=True)
class MemoryEnergy:
    """Per-iteration memory-system energy accounting (joules)."""
    read_j: float
    write_j: float
    refresh_j: float
    offchip_j: float

    @property
    def total_j(self) -> float:
        return self.read_j + self.write_j + self.refresh_j + self.offchip_j


def edram_energy(cfg: EDRAMConfig, read_bits: float, write_bits: float,
                 stored_bits: float, duration_s: float, temp_c: float,
                 needs_refresh: bool) -> MemoryEnergy:
    """Energy of serving ``read/write_bits`` of traffic over ``duration_s``.

    If the schedule's data lifetime exceeds retention (``needs_refresh``),
    every stored bit is refreshed each retention interval — the cost the
    CAMEL co-design removes.
    """
    refresh_j = 0.0
    if needs_refresh:
        n_refresh = duration_s / refresh_interval_s(temp_c)
        refresh_j = stored_bits * cfg.refresh_total_pj * 1e-12 * n_refresh
    return MemoryEnergy(
        read_j=read_bits * cfg.read_pj_per_bit * 1e-12,
        write_j=write_bits * cfg.write_pj_per_bit * 1e-12,
        refresh_j=refresh_j,
        offchip_j=0.0,
    )


def sram_energy(cfg: EDRAMConfig, read_bits: float, write_bits: float,
                offchip_bits: float) -> MemoryEnergy:
    return MemoryEnergy(
        read_j=read_bits * cfg.sram_read_pj_per_bit * 1e-12,
        write_j=write_bits * cfg.sram_write_pj_per_bit * 1e-12,
        refresh_j=0.0,
        offchip_j=offchip_bits * cfg.dram_pj_per_bit * 1e-12,
    )


def capacity_bits(cfg: EDRAMConfig) -> float:
    return cfg.n_banks * cfg.bank_kb * 1024 * 8
