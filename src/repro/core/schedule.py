"""Computation-pattern scheduler + discrete-event memory simulator
(CAMEL §IV, Figs 12–15).

Builds the dependency graph of one DuDNN training iteration, executes the
paper's pseudo-instruction order with the overwrite policy ("any value not
read again is dead"), and reports per-tensor lifetimes, peak live memory,
and read/write bit traffic.  Cross-validates the closed forms in
``core.lifetime`` (tests assert agreement within one op duration) and feeds
``core.hwmodel``'s energy accounting.

Ops carry *work* (:class:`OpWork` — MAC counts, port words, DMA bits),
not durations: a cost model (``repro.sim.cost``) prices work into seconds
at an operating point (``simulate(..., op_seconds=...)``), which is what
makes op latency frequency-dependent under DVFS.  ``Op.duration`` remains
as a derived back-compat property at the builder's baseline rate.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import networkx as nx

from repro.core.lifetime import DuBlockSpec, OpSpec

EVENT_KINDS = ("alloc", "write", "read", "free", "evict")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One tensor touch on the schedule timeline (consumed by the
    ``repro.memory`` controller's trace-driven replay).

    ``alloc`` marks data live at iteration start (no write energy);
    ``write``/``read`` carry the op's traffic; ``free`` is the overwrite
    point — the last reader has run and the words are dead.  ``evict``
    is a policy-driven drop (a KV entry past its retention deadline, a
    serving session preempted): like ``free`` it releases the words, but
    the allocator records the tensor as evicted — the data was dropped
    *before* its last reader, not after (``repro.serve`` counts these as
    its accuracy proxy).

    ``buffered`` marks whole-iteration activation buffers (the
    irreversible/FR arm's forward stash): the controller places them at
    full batch size — they are not streamed sample-by-sample through
    ping-pong buffers — and their residency counts unscaled against
    retention.
    """
    time: float
    op: str
    tensor: str
    kind: str
    bits: float
    buffered: bool = False


@dataclasses.dataclass(frozen=True)
class OpWork:
    """Hardware-independent *work* of one op — what it does, not how long
    it takes.  A cost model (``repro.sim.cost``) turns work into seconds
    at an operating point:

    ``macs``
        MAC count on the systolic array (eqs 3–5 use these; time is
        ``macs / effective-MAC-rate`` and the rate scales with clock).
    ``port_words``
        Explicit bank-port words the op moves outside its MAC stream
        (zero for the paper's ops — port timing is resolved per bank by
        the memory controller replay against the same clock).
    ``dma_bits``
        Off-chip DMA payload; priced against the wall-clock off-chip
        bandwidth (a DMA engine does not speed up when the core clocks
        down).
    """
    macs: float = 0.0
    port_words: float = 0.0
    dma_bits: float = 0.0


@dataclasses.dataclass(frozen=True)
class Op:
    """One scheduled op: *work* plus dataflow (reads/writes).

    ``duration`` is a **derived** property, not a stored field: it is
    ``work.macs / rate`` at the builder's baseline MAC/s (``rate``), the
    back-compat view for callers that used the pre-cost-model API.  The
    ``repro.sim`` pipeline ignores it and re-times ops through the arm's
    cost model (``simulate(..., op_seconds=...)``), which is what makes
    op latency frequency-dependent under DVFS.

    Legacy positional construction ``Op(name, seconds, reads, writes)``
    (a number where ``work`` goes) still works: the number is captured
    as an explicit ``duration_s`` pin with empty work.  Keyword
    construction ``Op(duration=...)`` is gone — pass ``duration_s=``
    (see docs/sim-api.md migration notes).
    """
    name: str
    work: OpWork
    reads: tuple
    writes: tuple
    rate: float = 0.0              # builder's baseline MAC/s
    duration_s: Optional[float] = None   # explicit pin; wins over work

    def __post_init__(self):
        if not isinstance(self.work, OpWork):    # legacy Op(name, secs, ...)
            object.__setattr__(self, "duration_s", float(self.work))
            object.__setattr__(self, "work", OpWork())

    @property
    def duration(self) -> float:
        """Seconds at the builder's baseline rate (back-compat view).

        Raises ``ValueError`` for an op that carries MAC work but no
        baseline ``rate`` — reading a duration off an untimed op is a
        bug (price it through a cost model instead), and silently
        returning 0.0 would yield all-zero schedules.
        """
        if self.duration_s is not None:
            return self.duration_s
        if self.work.macs == 0.0:
            return 0.0                 # fused/zero-work op
        if self.rate <= 0.0:
            raise ValueError(
                f"op {self.name!r} carries MAC work but no baseline rate; "
                f"build with R or price it via a cost model (op_seconds)")
        return self.work.macs / self.rate


@dataclasses.dataclass
class SimResult:
    """One simulated op stream: lifetimes/traffic in **bits** and
    **seconds** on the unconstrained (back-to-back) op timeline.

    ``schedule`` is the ordered ``[(op name, start_s, end_s), ...]``
    execution record — the closed-loop timeline model
    (``repro.sim.timeline``) walks it and pushes ops back on bank/port
    conflicts; ``trace`` carries the per-tensor :class:`TraceEvent`
    stream the memory controller replays.
    """
    lifetimes: dict            # tensor -> seconds between write & last read
    peak_live_bits: float
    read_bits: float
    write_bits: float
    total_time: float          # seconds; sum of op durations
    schedule: list             # [(op name, start_s, end_s), ...] in order
    trace: list = dataclasses.field(default_factory=list)  # TraceEvents

    @property
    def max_lifetime(self) -> float:
        return max(self.lifetimes.values()) if self.lifetimes else 0.0


def _tensor_bits(spec: OpSpec, bits_per_value: float) -> float:
    return spec.batch * spec.c_out * spec.width * spec.height * bits_per_value


def _mac(n_macs: float, R: float) -> dict:
    """Op kwargs for a MAC-work op at baseline rate ``R`` (MAC/s)."""
    return dict(work=OpWork(macs=n_macs), rate=R)


_FUSED = dict(work=OpWork())       # elementwise add/copy fused into a MAC op


def forward_ops(blocks: Sequence[DuBlockSpec], R: float = 0.0) -> list[Op]:
    """Fig 12(c)/(d): per layer — G, F1, add(y2), F2, add(y1).

    Ops carry *work* (MAC counts); ``R`` is only the baseline MAC/s their
    back-compat ``duration`` property resolves against.
    """
    ops = []
    for l, b in enumerate(blocks):
        ops += [
            Op(f"G{l}", reads=(f"k{l}",), writes=(f"k{l+1}",),
               **_mac(b.g.macs, R)),
            Op(f"F1_{l}", reads=(f"b1_{l}", f"k{l+1}"), writes=(f"t{l}",),
               **_mac(b.f1.macs, R)),
            Op(f"ADD2_{l}", reads=(f"b2_{l}", f"t{l}"),
               writes=(f"b2_{l+1}",), **_FUSED),
            Op(f"F2_{l}", reads=(f"b2_{l+1}",), writes=(f"s{l}",),
               **_mac(b.f2.macs, R)),
            Op(f"ADD1_{l}", reads=(f"b1_{l}", f"s{l}"),
               writes=(f"b1_{l+1}",), **_FUSED),
        ]
    return ops


def backward_ops(blocks: Sequence[DuBlockSpec], R: float = 0.0) -> list[Op]:
    """Fig 14(c)/15(a): reversed walk with recompute + gradient ops."""
    ops = []
    L = len(blocks)
    for l in reversed(range(L)):
        b = blocks[l]
        m1, m2 = b.f1.macs_out, b.f2.macs_out
        ops += [
            # eq 2 input recompute
            Op(f"RF2_{l}", reads=(f"b2_{l+1}",), writes=(f"rs{l}",),
               **_mac(m2, R)),
            Op(f"SUBX1_{l}", reads=(f"b1_{l+1}", f"rs{l}"),
               writes=(f"b1_{l}",), **_FUSED),
            Op(f"RF1_{l}", reads=(f"b1_{l}",), writes=(f"rt{l}",),
               **_mac(m1, R)),
            Op(f"SUBX2_{l}", reads=(f"b2_{l+1}", f"rt{l}"),
               writes=(f"b2_{l}",), **_FUSED),
            # input gradients: m = g2 + U2a(g1); s = g1 + U1a(m)
            Op(f"U2A_{l}", reads=(f"g1_{l+1}",), writes=(f"u2a{l}",),
               **_mac(m2, R)),
            Op(f"ADDM_{l}", reads=(f"g2_{l+1}", f"u2a{l}"),
               writes=(f"m{l}",), **_FUSED),
            # weight gradients
            Op(f"U2W_{l}", reads=(f"g1_{l+1}", f"b2_{l+1}"),
               writes=(f"q2_{l}",), **_mac(m2, R)),
            Op(f"U1A_{l}", reads=(f"m{l}",), writes=(f"u1a{l}",),
               **_mac(m1, R)),
            Op(f"ADDS_{l}", reads=(f"g1_{l+1}", f"u1a{l}"),
               writes=(f"g1_{l}",), **_FUSED),
            Op(f"U1W_{l}", reads=(f"m{l}", f"b1_{l}"), writes=(f"q1_{l}",),
               **_mac(m1, R)),
            Op(f"COPYG2_{l}", reads=(f"m{l}",), writes=(f"g2_{l}",),
               **_FUSED),
        ]
    return ops


def irreversible_training_ops(
        blocks: Sequence[DuBlockSpec], R: float = 0.0) -> tuple[list, frozenset]:
    """One iteration of the irreversible (FR) baseline on a single timeline:
    whole-iteration activation buffering instead of eq-2 recompute.

    The forward pass is the same dataflow as :func:`forward_ops`, but each
    branch activation is additionally copied into a whole-iteration buffer
    (``SAVE*`` ops writing ``sv*`` tensors) right after production — the
    conventional training discipline the reversible pattern eliminates.
    The backward pass fetches each buffer back into a working copy
    (``FETCH*``) instead of recomputing, then runs the same gradient ops.
    SAVE/FETCH are zero-duration (DMA overlapped with compute); their
    *traffic* is what the memory controller charges, and any buffer that
    does not fit on-chip spills — one store plus one load per tensor.

    Returns ``(ops, buffered)`` where ``buffered`` is the set of
    whole-iteration buffer tensor names (``simulate(..., buffered=...)``
    tags their trace events so the controller places them at full batch
    size).
    """
    L = len(blocks)
    ops: list[Op] = []
    for l, b in enumerate(blocks):
        ops += [
            Op(f"SAVE1_{l}", reads=(f"b1_{l}",), writes=(f"sv1_{l}",),
               **_FUSED),
            Op(f"G{l}", reads=(f"k{l}",), writes=(f"k{l+1}",),
               **_mac(b.g.macs, R)),
            Op(f"F1_{l}", reads=(f"b1_{l}", f"k{l+1}"), writes=(f"t{l}",),
               **_mac(b.f1.macs, R)),
            Op(f"ADD2_{l}", reads=(f"b2_{l}", f"t{l}"),
               writes=(f"b2_{l+1}",), **_FUSED),
            Op(f"SAVE2_{l}", reads=(f"b2_{l+1}",), writes=(f"sv2_{l}",),
               **_FUSED),
            Op(f"F2_{l}", reads=(f"b2_{l+1}",), writes=(f"s{l}",),
               **_mac(b.f2.macs, R)),
            Op(f"ADD1_{l}", reads=(f"b1_{l}", f"s{l}"),
               writes=(f"b1_{l+1}",), **_FUSED),
        ]
    # the loss head turns the final activations into output gradients
    ops.append(Op("LOSS", reads=(f"b1_{L}", f"b2_{L}"),
                  writes=(f"g1_{L}", f"g2_{L}"), **_FUSED))
    for l in reversed(range(L)):
        b = blocks[l]
        m1, m2 = b.f1.macs_out, b.f2.macs_out
        ops += [
            # buffered activations come back instead of eq-2 recompute
            Op(f"FETCH2_{l}", reads=(f"sv2_{l}",), writes=(f"b2f_{l}",),
               **_FUSED),
            Op(f"U2A_{l}", reads=(f"g1_{l+1}",), writes=(f"u2a{l}",),
               **_mac(m2, R)),
            Op(f"ADDM_{l}", reads=(f"g2_{l+1}", f"u2a{l}"),
               writes=(f"m{l}",), **_FUSED),
            Op(f"U2W_{l}", reads=(f"g1_{l+1}", f"b2f_{l}"),
               writes=(f"q2_{l}",), **_mac(m2, R)),
            Op(f"U1A_{l}", reads=(f"m{l}",), writes=(f"u1a{l}",),
               **_mac(m1, R)),
            Op(f"ADDS_{l}", reads=(f"g1_{l+1}", f"u1a{l}"),
               writes=(f"g1_{l}",), **_FUSED),
            Op(f"FETCH1_{l}", reads=(f"sv1_{l}",), writes=(f"b1f_{l}",),
               **_FUSED),
            Op(f"U1W_{l}", reads=(f"m{l}", f"b1f_{l}"),
               writes=(f"q1_{l}",), **_mac(m1, R)),
            Op(f"COPYG2_{l}", reads=(f"m{l}",), writes=(f"g2_{l}",),
               **_FUSED),
        ]
    buffered = frozenset(f"sv{i}_{l}" for i in (1, 2) for l in range(L))
    return ops, buffered


# ------------------------------------------------------- serving builders

def prefill_op(name: str, macs: float, kv_writes: Sequence[str],
               rate: float = 0.0) -> Op:
    """One serving *prefill* op: process a request's whole prompt and
    append one KV entry per (layer, position) — ``kv_writes`` — at the
    op's end.  Prefill reads no cache (the prompt streams through the
    array); its MAC work covers the projections plus causal attention
    over the growing prefix.  Used by the ``repro.serve`` decode-trace
    generator."""
    return Op(name, work=OpWork(macs=macs), reads=(),
              writes=tuple(kv_writes), rate=rate)


def decode_op(name: str, macs: float, kv_reads: Sequence[str],
              kv_writes: Sequence[str], rate: float = 0.0) -> Op:
    """One serving *decode* op: generate one token for one session.

    ``kv_reads`` is the session's live cache — every entry written at an
    earlier position is re-read here (token-position-dependent lifetime:
    an entry lives from its write until session end, touched every
    step), so attention port traffic grows with cache length.
    ``kv_writes`` is the new position's entry per layer, landing at the
    op's end.  MAC work = per-token projections + attention over the
    live cache (+ any recompute of expired entries the KV policy
    schedules onto this op)."""
    return Op(name, work=OpWork(macs=macs), reads=tuple(kv_reads),
              writes=tuple(kv_writes), rate=rate)


def dependency_graph(ops: Sequence[Op]) -> nx.DiGraph:
    """Producer→consumer DAG (Fig 12b / 14b)."""
    g = nx.DiGraph()
    last_writer: dict = {}
    for op in ops:
        g.add_node(op.name, duration=op.duration)
        for t in op.reads:
            if t in last_writer:
                g.add_edge(last_writer[t], op.name, tensor=t)
        for t in op.writes:
            last_writer[t] = op.name
    if not nx.is_directed_acyclic_graph(g):
        raise ValueError("computation pattern has a cycle")
    return g


def _sizes(blocks: Sequence[DuBlockSpec], bits: float) -> dict:
    sizes: dict = {}
    for l, b in enumerate(blocks):
        br = _tensor_bits(b.f1, bits)
        bk = _tensor_bits(b.g, bits)
        for name in (f"b1_{l}", f"b2_{l}", f"b1_{l+1}", f"b2_{l+1}",
                     f"t{l}", f"s{l}", f"rs{l}", f"rt{l}", f"u2a{l}",
                     f"u1a{l}", f"m{l}", f"g1_{l}", f"g2_{l}",
                     f"g1_{l+1}", f"g2_{l+1}", f"q1_{l}", f"q2_{l}",
                     # irreversible arm: whole-iteration activation saves
                     # and their backward working copies
                     f"sv1_{l}", f"sv2_{l}", f"b1f_{l}", f"b2f_{l}"):
            sizes[name] = br
        sizes[f"k{l}"] = bk
        sizes[f"k{l+1}"] = bk
    return sizes


def simulate(ops: Sequence[Op], blocks: Sequence[DuBlockSpec],
             bits_per_value: float = 58 / 9,
             live_at_start: Sequence[str] = (),
             buffered: Sequence[str] = (),
             op_seconds: Optional[Callable[[Op], float]] = None) -> SimResult:
    """Execute ``ops`` in order with the overwrite policy; measure lifetimes.

    A tensor becomes live at its producing op's end and dies after its last
    reader finishes (it is overwritten — Fig 12c's "x2 can be overwritten
    once y3 is produced").  Tensors named in ``buffered`` are tagged as
    whole-iteration buffers on their trace events (see :class:`TraceEvent`).

    ``op_seconds`` is the cost-model hook: a callable resolving one op's
    *work* into seconds (``repro.sim.cost.op_timer`` builds one from an
    operating point).  ``None`` falls back to each op's back-compat
    ``duration`` property — the builder's baseline rate.
    """
    sizes = _sizes(blocks, bits_per_value)
    buffered = frozenset(buffered)
    if op_seconds is None:
        def op_seconds(op):
            return op.duration
    last_read_op: dict = {}
    for op in ops:
        for t in op.reads:
            last_read_op[t] = op.name

    t_now = 0.0
    write_time: dict = {}
    lifetimes: dict = {}
    # boot tensors occupy real storage until their last reader frees them
    live: dict = {t: sizes.get(t, 0.0) for t in live_at_start}
    peak = sum(live.values())
    read_bits = write_bits = 0.0
    schedule = []
    trace = [TraceEvent(time=0.0, op="<boot>", tensor=t, kind="alloc",
                        bits=sizes.get(t, 0.0), buffered=t in buffered)
             for t in live_at_start]
    for op in ops:
        start, end = t_now, t_now + op_seconds(op)
        t_now = end
        schedule.append((op.name, start, end))
        for t in op.reads:
            read_bits += sizes.get(t, 0.0)
            trace.append(TraceEvent(time=start, op=op.name, tensor=t,
                                    kind="read", bits=sizes.get(t, 0.0),
                                    buffered=t in buffered))
        for t in op.writes:
            write_bits += sizes.get(t, 0.0)
            write_time[t] = end
            live[t] = sizes.get(t, 0.0)
            trace.append(TraceEvent(time=end, op=op.name, tensor=t,
                                    kind="write", bits=sizes.get(t, 0.0),
                                    buffered=t in buffered))
        peak = max(peak, sum(live.values()))
        # overwrite policy: free every tensor whose last reader just ran
        for t in op.reads:
            if last_read_op.get(t) == op.name:
                if t in write_time:
                    lifetimes[t] = end - write_time.pop(t)
                if t in live:
                    trace.append(TraceEvent(time=end, op=op.name, tensor=t,
                                            kind="free",
                                            bits=sizes.get(t, 0.0),
                                            buffered=t in buffered))
                live.pop(t, None)
    return SimResult(lifetimes=lifetimes, peak_live_bits=peak,
                     read_bits=read_bits, write_bits=write_bits,
                     total_time=t_now, schedule=schedule, trace=trace)


def simulate_training_iteration(blocks: Sequence[DuBlockSpec], R: float,
                                bits_per_value: float = 58 / 9,
                                op_seconds=None):
    """Forward + backward of one iteration; returns (fwd, bwd) SimResults.

    ``op_seconds`` overrides the per-op work→seconds resolution (see
    :func:`simulate`); the default prices each op at baseline rate ``R``.
    """
    L = len(blocks)
    fwd = simulate(forward_ops(blocks, R), blocks, bits_per_value,
                   live_at_start=("b1_0", "b2_0", "k0"),
                   op_seconds=op_seconds)
    bwd = simulate(backward_ops(blocks, R), blocks, bits_per_value,
                   live_at_start=(f"b1_{L}", f"b2_{L}",
                                  f"g1_{L}", f"g2_{L}"),
                   op_seconds=op_seconds)
    return fwd, bwd


def simulate_irreversible_iteration(blocks: Sequence[DuBlockSpec], R: float,
                                    bits_per_value: float = 16.0,
                                    op_seconds=None) -> SimResult:
    """One FR-baseline iteration on a single timeline (forward + buffered
    backward); the whole-iteration activation buffers appear as ``buffered``
    trace events so the memory controller models their spills."""
    ops, buffered = irreversible_training_ops(blocks, R)
    return simulate(ops, blocks, bits_per_value,
                    live_at_start=("b1_0", "b2_0", "k0"), buffered=buffered,
                    op_seconds=op_seconds)
