"""Computation-pattern scheduler + discrete-event memory simulator
(CAMEL §IV, Figs 12–15).

Builds the dependency graph of one DuDNN training iteration, executes the
paper's pseudo-instruction order with the overwrite policy ("any value not
read again is dead"), and reports per-tensor lifetimes, peak live memory,
and read/write bit traffic.  Cross-validates the closed forms in
``core.lifetime`` (tests assert agreement within one op duration) and feeds
``core.hwmodel``'s energy accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import networkx as nx

from repro.core.lifetime import DuBlockSpec, OpSpec, latency

EVENT_KINDS = ("alloc", "write", "read", "free")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One tensor touch on the schedule timeline (consumed by the
    ``repro.memory`` controller's trace-driven replay).

    ``alloc`` marks data live at iteration start (no write energy);
    ``write``/``read`` carry the op's traffic; ``free`` is the overwrite
    point — the last reader has run and the words are dead.

    ``buffered`` marks whole-iteration activation buffers (the
    irreversible/FR arm's forward stash): the controller places them at
    full batch size — they are not streamed sample-by-sample through
    ping-pong buffers — and their residency counts unscaled against
    retention.
    """
    time: float
    op: str
    tensor: str
    kind: str
    bits: float
    buffered: bool = False


@dataclasses.dataclass(frozen=True)
class Op:
    name: str
    duration: float
    reads: tuple
    writes: tuple


@dataclasses.dataclass
class SimResult:
    """One simulated op stream: lifetimes/traffic in **bits** and
    **seconds** on the unconstrained (back-to-back) op timeline.

    ``schedule`` is the ordered ``[(op name, start_s, end_s), ...]``
    execution record — the closed-loop timeline model
    (``repro.sim.timeline``) walks it and pushes ops back on bank/port
    conflicts; ``trace`` carries the per-tensor :class:`TraceEvent`
    stream the memory controller replays.
    """
    lifetimes: dict            # tensor -> seconds between write & last read
    peak_live_bits: float
    read_bits: float
    write_bits: float
    total_time: float          # seconds; sum of op durations
    schedule: list             # [(op name, start_s, end_s), ...] in order
    trace: list = dataclasses.field(default_factory=list)  # TraceEvents

    @property
    def max_lifetime(self) -> float:
        return max(self.lifetimes.values()) if self.lifetimes else 0.0


def _tensor_bits(spec: OpSpec, bits_per_value: float) -> float:
    return spec.batch * spec.c_out * spec.width * spec.height * bits_per_value


def forward_ops(blocks: Sequence[DuBlockSpec], R: float) -> list[Op]:
    """Fig 12(c)/(d): per layer — G, F1, add(y2), F2, add(y1)."""
    ops = []
    for l, b in enumerate(blocks):
        tg, t1, t2 = latency(b.g.macs, R), latency(b.f1.macs, R), \
            latency(b.f2.macs, R)
        ops += [
            Op(f"G{l}", tg, (f"k{l}",), (f"k{l+1}",)),
            Op(f"F1_{l}", t1, (f"b1_{l}", f"k{l+1}"), (f"t{l}",)),
            Op(f"ADD2_{l}", 0.0, (f"b2_{l}", f"t{l}"), (f"b2_{l+1}",)),
            Op(f"F2_{l}", t2, (f"b2_{l+1}",), (f"s{l}",)),
            Op(f"ADD1_{l}", 0.0, (f"b1_{l}", f"s{l}"), (f"b1_{l+1}",)),
        ]
    return ops


def backward_ops(blocks: Sequence[DuBlockSpec], R: float) -> list[Op]:
    """Fig 14(c)/15(a): reversed walk with recompute + gradient ops."""
    ops = []
    L = len(blocks)
    for l in reversed(range(L)):
        b = blocks[l]
        t1, t2 = latency(b.f1.macs_out, R), latency(b.f2.macs_out, R)
        ops += [
            # eq 2 input recompute
            Op(f"RF2_{l}", t2, (f"b2_{l+1}",), (f"rs{l}",)),
            Op(f"SUBX1_{l}", 0.0, (f"b1_{l+1}", f"rs{l}"), (f"b1_{l}",)),
            Op(f"RF1_{l}", t1, (f"b1_{l}",), (f"rt{l}",)),
            Op(f"SUBX2_{l}", 0.0, (f"b2_{l+1}", f"rt{l}"), (f"b2_{l}",)),
            # input gradients: m = g2 + U2a(g1); s = g1 + U1a(m)
            Op(f"U2A_{l}", t2, (f"g1_{l+1}",), (f"u2a{l}",)),
            Op(f"ADDM_{l}", 0.0, (f"g2_{l+1}", f"u2a{l}"), (f"m{l}",)),
            # weight gradients
            Op(f"U2W_{l}", t2, (f"g1_{l+1}", f"b2_{l+1}"), (f"q2_{l}",)),
            Op(f"U1A_{l}", t1, (f"m{l}",), (f"u1a{l}",)),
            Op(f"ADDS_{l}", 0.0, (f"g1_{l+1}", f"u1a{l}"), (f"g1_{l}",)),
            Op(f"U1W_{l}", t1, (f"m{l}", f"b1_{l}"), (f"q1_{l}",)),
            Op(f"COPYG2_{l}", 0.0, (f"m{l}",), (f"g2_{l}",)),
        ]
    return ops


def irreversible_training_ops(
        blocks: Sequence[DuBlockSpec], R: float) -> tuple[list, frozenset]:
    """One iteration of the irreversible (FR) baseline on a single timeline:
    whole-iteration activation buffering instead of eq-2 recompute.

    The forward pass is the same dataflow as :func:`forward_ops`, but each
    branch activation is additionally copied into a whole-iteration buffer
    (``SAVE*`` ops writing ``sv*`` tensors) right after production — the
    conventional training discipline the reversible pattern eliminates.
    The backward pass fetches each buffer back into a working copy
    (``FETCH*``) instead of recomputing, then runs the same gradient ops.
    SAVE/FETCH are zero-duration (DMA overlapped with compute); their
    *traffic* is what the memory controller charges, and any buffer that
    does not fit on-chip spills — one store plus one load per tensor.

    Returns ``(ops, buffered)`` where ``buffered`` is the set of
    whole-iteration buffer tensor names (``simulate(..., buffered=...)``
    tags their trace events so the controller places them at full batch
    size).
    """
    L = len(blocks)
    ops: list[Op] = []
    for l, b in enumerate(blocks):
        tg, t1, t2 = latency(b.g.macs, R), latency(b.f1.macs, R), \
            latency(b.f2.macs, R)
        ops += [
            Op(f"SAVE1_{l}", 0.0, (f"b1_{l}",), (f"sv1_{l}",)),
            Op(f"G{l}", tg, (f"k{l}",), (f"k{l+1}",)),
            Op(f"F1_{l}", t1, (f"b1_{l}", f"k{l+1}"), (f"t{l}",)),
            Op(f"ADD2_{l}", 0.0, (f"b2_{l}", f"t{l}"), (f"b2_{l+1}",)),
            Op(f"SAVE2_{l}", 0.0, (f"b2_{l+1}",), (f"sv2_{l}",)),
            Op(f"F2_{l}", t2, (f"b2_{l+1}",), (f"s{l}",)),
            Op(f"ADD1_{l}", 0.0, (f"b1_{l}", f"s{l}"), (f"b1_{l+1}",)),
        ]
    # the loss head turns the final activations into output gradients
    ops.append(Op("LOSS", 0.0, (f"b1_{L}", f"b2_{L}"),
                  (f"g1_{L}", f"g2_{L}")))
    for l in reversed(range(L)):
        b = blocks[l]
        t1, t2 = latency(b.f1.macs_out, R), latency(b.f2.macs_out, R)
        ops += [
            # buffered activations come back instead of eq-2 recompute
            Op(f"FETCH2_{l}", 0.0, (f"sv2_{l}",), (f"b2f_{l}",)),
            Op(f"U2A_{l}", t2, (f"g1_{l+1}",), (f"u2a{l}",)),
            Op(f"ADDM_{l}", 0.0, (f"g2_{l+1}", f"u2a{l}"), (f"m{l}",)),
            Op(f"U2W_{l}", t2, (f"g1_{l+1}", f"b2f_{l}"), (f"q2_{l}",)),
            Op(f"U1A_{l}", t1, (f"m{l}",), (f"u1a{l}",)),
            Op(f"ADDS_{l}", 0.0, (f"g1_{l+1}", f"u1a{l}"), (f"g1_{l}",)),
            Op(f"FETCH1_{l}", 0.0, (f"sv1_{l}",), (f"b1f_{l}",)),
            Op(f"U1W_{l}", t1, (f"m{l}", f"b1f_{l}"), (f"q1_{l}",)),
            Op(f"COPYG2_{l}", 0.0, (f"m{l}",), (f"g2_{l}",)),
        ]
    buffered = frozenset(f"sv{i}_{l}" for i in (1, 2) for l in range(L))
    return ops, buffered


def dependency_graph(ops: Sequence[Op]) -> nx.DiGraph:
    """Producer→consumer DAG (Fig 12b / 14b)."""
    g = nx.DiGraph()
    last_writer: dict = {}
    for op in ops:
        g.add_node(op.name, duration=op.duration)
        for t in op.reads:
            if t in last_writer:
                g.add_edge(last_writer[t], op.name, tensor=t)
        for t in op.writes:
            last_writer[t] = op.name
    if not nx.is_directed_acyclic_graph(g):
        raise ValueError("computation pattern has a cycle")
    return g


def _sizes(blocks: Sequence[DuBlockSpec], bits: float) -> dict:
    sizes: dict = {}
    for l, b in enumerate(blocks):
        br = _tensor_bits(b.f1, bits)
        bk = _tensor_bits(b.g, bits)
        for name in (f"b1_{l}", f"b2_{l}", f"b1_{l+1}", f"b2_{l+1}",
                     f"t{l}", f"s{l}", f"rs{l}", f"rt{l}", f"u2a{l}",
                     f"u1a{l}", f"m{l}", f"g1_{l}", f"g2_{l}",
                     f"g1_{l+1}", f"g2_{l+1}", f"q1_{l}", f"q2_{l}",
                     # irreversible arm: whole-iteration activation saves
                     # and their backward working copies
                     f"sv1_{l}", f"sv2_{l}", f"b1f_{l}", f"b2f_{l}"):
            sizes[name] = br
        sizes[f"k{l}"] = bk
        sizes[f"k{l+1}"] = bk
    return sizes


def simulate(ops: Sequence[Op], blocks: Sequence[DuBlockSpec],
             bits_per_value: float = 58 / 9,
             live_at_start: Sequence[str] = (),
             buffered: Sequence[str] = ()) -> SimResult:
    """Execute ``ops`` in order with the overwrite policy; measure lifetimes.

    A tensor becomes live at its producing op's end and dies after its last
    reader finishes (it is overwritten — Fig 12c's "x2 can be overwritten
    once y3 is produced").  Tensors named in ``buffered`` are tagged as
    whole-iteration buffers on their trace events (see :class:`TraceEvent`).
    """
    sizes = _sizes(blocks, bits_per_value)
    buffered = frozenset(buffered)
    last_read_op: dict = {}
    for op in ops:
        for t in op.reads:
            last_read_op[t] = op.name

    t_now = 0.0
    write_time: dict = {}
    lifetimes: dict = {}
    # boot tensors occupy real storage until their last reader frees them
    live: dict = {t: sizes.get(t, 0.0) for t in live_at_start}
    peak = sum(live.values())
    read_bits = write_bits = 0.0
    schedule = []
    trace = [TraceEvent(time=0.0, op="<boot>", tensor=t, kind="alloc",
                        bits=sizes.get(t, 0.0), buffered=t in buffered)
             for t in live_at_start]
    for op in ops:
        start, end = t_now, t_now + op.duration
        t_now = end
        schedule.append((op.name, start, end))
        for t in op.reads:
            read_bits += sizes.get(t, 0.0)
            trace.append(TraceEvent(time=start, op=op.name, tensor=t,
                                    kind="read", bits=sizes.get(t, 0.0),
                                    buffered=t in buffered))
        for t in op.writes:
            write_bits += sizes.get(t, 0.0)
            write_time[t] = end
            live[t] = sizes.get(t, 0.0)
            trace.append(TraceEvent(time=end, op=op.name, tensor=t,
                                    kind="write", bits=sizes.get(t, 0.0),
                                    buffered=t in buffered))
        peak = max(peak, sum(live.values()))
        # overwrite policy: free every tensor whose last reader just ran
        for t in op.reads:
            if last_read_op.get(t) == op.name:
                if t in write_time:
                    lifetimes[t] = end - write_time.pop(t)
                if t in live:
                    trace.append(TraceEvent(time=end, op=op.name, tensor=t,
                                            kind="free",
                                            bits=sizes.get(t, 0.0),
                                            buffered=t in buffered))
                live.pop(t, None)
    return SimResult(lifetimes=lifetimes, peak_live_bits=peak,
                     read_bits=read_bits, write_bits=write_bits,
                     total_time=t_now, schedule=schedule, trace=trace)


def simulate_training_iteration(blocks: Sequence[DuBlockSpec], R: float,
                                bits_per_value: float = 58 / 9):
    """Forward + backward of one iteration; returns (fwd, bwd) SimResults."""
    L = len(blocks)
    fwd = simulate(forward_ops(blocks, R), blocks, bits_per_value,
                   live_at_start=("b1_0", "b2_0", "k0"))
    bwd = simulate(backward_ops(blocks, R), blocks, bits_per_value,
                   live_at_start=(f"b1_{L}", f"b2_{L}",
                                  f"g1_{L}", f"g2_{L}"))
    return fwd, bwd


def simulate_irreversible_iteration(blocks: Sequence[DuBlockSpec], R: float,
                                    bits_per_value: float = 16.0
                                    ) -> SimResult:
    """One FR-baseline iteration on a single timeline (forward + buffered
    backward); the whole-iteration activation buffers appear as ``buffered``
    trace events so the memory controller models their spills."""
    ops, buffered = irreversible_training_ops(blocks, R)
    return simulate(ops, blocks, bits_per_value,
                    live_at_start=("b1_0", "b2_0", "k0"), buffered=buffered)
