"""CAMEL system-level performance/energy model (§V, §VI-D/E/F).

Combines the scheduler's traffic/lifetime numbers with the eDRAM model and
a systolic-array throughput model to produce per-iteration latency/energy,
and TTA/ETA comparisons across the paper's four system arms (Fig 24):

  DuDNN+CAMEL   — reversible branch, eDRAM activations, refresh-free
  FR+SRAM-only  — irreversible baseline, SRAM + off-chip DRAM spills
  CA+CAMEL      — chain (reversible cascade after backbone)
  BO+CAMEL      — branch alone (no backbone guidance)

The hardware constants live in ``EDRAMConfig`` / here; iteration *counts*
come from measured convergence (benchmarks/table2) or the paper's relative
convergence behaviour when a full training run is out of scope.

.. deprecated::
    The simulation entry points moved to ``repro.sim`` — a staged pipeline
    behind ``sim.run(arm)`` that routes *every* arm (including FR/SRAM)
    through the trace-driven memory controller.  ``iteration()`` /
    ``tta_eta()`` / ``SRAM_ONLY`` remain as thin shims that emit
    ``DeprecationWarning`` with ``stacklevel=2`` (the warning points at
    *your* call site, including for the module-level ``SRAM_ONLY``
    attribute, via ``__getattr__``) and delegate; ``SystemConfig`` stays
    canonical here.  Migration recipes: ``docs/sim-api.md``.

.. deprecated::
    Reading ``SystemConfig.freq_hz`` directly for *timing* is deprecated:
    it is only the default operating point the ``FixedClock`` cost model
    resolves when ``Arm.cost`` is unset.  Timing code must price work
    through the resolved cost model (``repro.sim.cost.resolve_cost`` /
    the pipeline's ``cost`` stage, surfaced as ``ArmReport.freq_hz``) —
    a raw ``cfg.freq_hz`` read silently ignores DVFS operating points.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

from repro.core import edram as ed
from repro.core.lifetime import DuBlockSpec

BFP_BITS = 58 / 9          # §III-E: 6.44 bits/value
FP16_BITS = 16.0


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    name: str = "CAMEL"
    array: int = 6                 # §V-A: 6×6 systolic PEs
    # §VI-D — the *nominal* clock: the FixedClock default.  Deprecated as
    # a raw timing read; resolve through the cost model (module docstring)
    freq_hz: float = 500e6
    bfp_group: int = 3
    mac_pj: float = 0.35           # BFP 6-bit-mantissa MAC (modeled 16nm)
    mac_pj_fp16: float = 0.9
    use_edram: bool = True
    onchip_bits: float = 12 * 32 * 1024 * 8   # 12×32KB eDRAM
    temp_c: float = 60.0
    edram: ed.EDRAMConfig = ed.EDRAMConfig()
    offchip_bw_bps: float = 272e9  # bits/s; LPDDR5-class x32, 34 GB/s
    # bank-level controller (repro.memory): trace-driven replay of the
    # schedule instead of the scalar stored/needs_refresh arithmetic
    use_controller: bool = True
    refresh_policy: str = "selective"   # always | none | selective
    # refresh pulse unit: "bank" (one pulse per bank per retention tick,
    # the conventional discipline) or "row" (one pulse per occupied
    # wordline — the paper controller's granularity; compute interleaves
    # with refresh at row boundaries).  Refresh energy is granularity-
    # invariant; only refresh stalls / hiding move.
    refresh_granularity: str = "bank"   # bank | row
    alloc_policy: str = "pingpong"      # pingpong | first_fit | lifetime
    # charge the on-chip tier's leakage power (EDRAMConfig.leakage_mw_per_kb
    # or sram_leakage_mw_per_kb × the tier's capacity in kB) over each
    # iteration's wall-clock latency.  Off by default — the golden-pinned
    # seed numbers predate the leakage term; enabling it makes slow DVFS
    # operating points pay for the time they stretch over, so the
    # energy-optimal point becomes interior instead of the slowest clock.
    charge_leakage: bool = False
    # read-triggered restore (Kelle-style refresh skipping, the
    # ``repro.serve`` KV-policy substrate): every on-chip read pays the
    # refresh restore phase (write-back of the destructively sensed
    # value) and resets the touched rows' decay clocks, so under the
    # ``selective`` policy a bank whose entries are re-read within
    # retention never needs a refresh pulse.  Off for the training arms
    # (their golden pins predate it).
    reads_restore: bool = False
    # trace-replay engine: "python" (the scalar reference walk) or
    # "vector" (numpy interval engine, bit-identical reports — see
    # repro.memory.vector).  Span recording (repro.obs) always runs on
    # the reference walk: a recorder downgrades "vector" with a logged
    # warning.
    replay_backend: str = "python"      # python | vector
    # bank count the controller splits ``onchip_bits`` into when
    # ``use_edram=False`` (the paper's 4×48KB activation SRAMs)
    sram_banks: int = 4
    # hybrid SRAM+eDRAM memory (repro.memory.tiers): a tuple of TierSpec
    # replaces the homogeneous bank array with a multi-tier MemorySystem
    # — ``alloc_policy`` then names a tier-routing policy (e.g.
    # "lifetime_tiered") and ``onchip_bits`` should equal the tiers'
    # total capacity.  ``None`` (default) keeps the single-tier model;
    # build iso-area splits with ``repro.memory.tiers.iso_area_tiers``.
    tiers: object = None


_SRAM_ONLY = SystemConfig(
    name="SRAM-only", array=4,      # §VI-F: same area ⇒ smaller array
    use_edram=False,
    onchip_bits=4 * 48 * 1024 * 8,  # 4×48KB activation SRAMs
)


def __getattr__(name: str):
    if name == "SRAM_ONLY":
        warnings.warn(
            "core.hwmodel.SRAM_ONLY is deprecated; use "
            "repro.sim.get_arm('FR+SRAM').system",
            DeprecationWarning, stacklevel=2)
        return _SRAM_ONLY
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass(frozen=True)
class IterationReport:
    latency_s: float
    energy_j: float
    compute_j: float
    memory_j: float
    max_lifetime_s: float
    refresh_free: bool
    peak_live_bits: float
    offchip_bits: float
    # bank-level controller results (None on the scalar/SRAM paths); the
    # scalar edram_energy total is kept as a cross-validation oracle
    controller: object = None
    scalar_memory_j: float = 0.0
    stall_s: float = 0.0


def _iteration(cfg: SystemConfig, blocks: Sequence[DuBlockSpec],
               reversible: bool = True) -> IterationReport:
    """Delegate to the ``repro.sim`` pipeline; repackage as the legacy
    :class:`IterationReport` (no warning — the shims share this path)."""
    from repro import sim          # late import: sim imports this module
    rep = sim.run(sim.Arm(name=cfg.name, system=cfg, reversible=reversible,
                          workload=None, blocks=tuple(blocks),
                          iters_to_target=None))
    return IterationReport(
        latency_s=rep.latency_s,
        energy_j=rep.energy_j,
        compute_j=rep.compute_j,
        memory_j=rep.memory_j,
        max_lifetime_s=rep.max_lifetime_s,
        refresh_free=rep.refresh_free,
        peak_live_bits=rep.peak_live_bits,
        offchip_bits=rep.offchip_bits,
        controller=rep.controller,
        scalar_memory_j=rep.scalar_memory_j,
        stall_s=rep.stall_s,
    )


def iteration(cfg: SystemConfig, blocks: Sequence[DuBlockSpec],
              reversible: bool = True) -> IterationReport:
    """Latency + energy of one training iteration on ``cfg``.

    .. deprecated:: use ``repro.sim.run(sim.Arm(...))`` — same numbers,
       structured ``ArmReport``, and every arm through the controller.
    """
    warnings.warn(
        "core.hwmodel.iteration() is deprecated; use repro.sim.run(Arm(...))",
        DeprecationWarning, stacklevel=2)
    return _iteration(cfg, blocks, reversible)


def tta_eta(cfg: SystemConfig, blocks: Sequence[DuBlockSpec],
            iterations_to_target: float, reversible: bool = True):
    """Time/Energy-to-Accuracy (§VI-F): per-iteration cost × iterations.

    .. deprecated:: use ``repro.sim.run`` with ``Arm.iters_to_target`` set —
       the ArmReport carries ``tta_s``/``eta_j`` directly.
    """
    warnings.warn(
        "core.hwmodel.tta_eta() is deprecated; use repro.sim.run with "
        "Arm.iters_to_target set", DeprecationWarning, stacklevel=2)
    rep = _iteration(cfg, blocks, reversible)
    return {
        "tta_s": rep.latency_s * iterations_to_target,
        "eta_j": rep.energy_j * iterations_to_target,
        "iteration": rep,
    }
