"""CAMEL system-level performance/energy model (§V, §VI-D/E/F).

Combines the scheduler's traffic/lifetime numbers with the eDRAM model and
a systolic-array throughput model to produce per-iteration latency/energy,
and TTA/ETA comparisons across the paper's four system arms (Fig 24):

  DuDNN+CAMEL   — reversible branch, eDRAM activations, refresh-free
  FR+SRAM-only  — irreversible baseline, SRAM + off-chip DRAM spills
  CA+CAMEL      — chain (reversible cascade after backbone)
  BO+CAMEL      — branch alone (no backbone guidance)

The hardware constants live in ``EDRAMConfig`` / here; iteration *counts*
come from measured convergence (benchmarks/table2) or the paper's relative
convergence behaviour when a full training run is out of scope.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import edram as ed
from repro.core.lifetime import DuBlockSpec, array_throughput
from repro.core.schedule import simulate_training_iteration
from repro.memory import trace as mtr

BFP_BITS = 58 / 9          # §III-E: 6.44 bits/value
FP16_BITS = 16.0


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    name: str = "CAMEL"
    array: int = 6                 # §V-A: 6×6 systolic PEs
    freq_hz: float = 500e6         # §VI-D
    bfp_group: int = 3
    mac_pj: float = 0.35           # BFP 6-bit-mantissa MAC (modeled 16nm)
    mac_pj_fp16: float = 0.9
    use_edram: bool = True
    onchip_bits: float = 12 * 32 * 1024 * 8   # 12×32KB eDRAM
    temp_c: float = 60.0
    edram: ed.EDRAMConfig = ed.EDRAMConfig()
    offchip_bw_bps: float = 272e9  # bits/s; LPDDR5-class x32, 34 GB/s
    # bank-level controller (repro.memory): trace-driven replay of the
    # schedule instead of the scalar stored/needs_refresh arithmetic
    use_controller: bool = True
    refresh_policy: str = "selective"   # always | none | selective
    alloc_policy: str = "pingpong"      # pingpong | first_fit | lifetime


SRAM_ONLY = SystemConfig(
    name="SRAM-only", array=4,      # §VI-F: same area ⇒ smaller array
    use_edram=False,
    onchip_bits=4 * 48 * 1024 * 8,  # 4×48KB activation SRAMs
)


@dataclasses.dataclass(frozen=True)
class IterationReport:
    latency_s: float
    energy_j: float
    compute_j: float
    memory_j: float
    max_lifetime_s: float
    refresh_free: bool
    peak_live_bits: float
    offchip_bits: float
    # bank-level controller results (None on the scalar/SRAM paths); the
    # scalar edram_energy total is kept as a cross-validation oracle
    controller: object = None
    scalar_memory_j: float = 0.0
    stall_s: float = 0.0


def iteration(cfg: SystemConfig, blocks: Sequence[DuBlockSpec],
              reversible: bool = True) -> IterationReport:
    """Latency + energy of one training iteration on ``cfg``.

    ``reversible=False`` models the FI/FR arm: all forward activations are
    buffered for the whole iteration (lifetime = iteration time) and any
    overflow beyond on-chip capacity spills off-chip (twice: store + load).
    """
    bits = BFP_BITS if cfg.use_edram else FP16_BITS
    specs = [s for b in blocks for s in (b.f1, b.f2, b.g)]
    R = array_throughput(cfg.array, cfg.freq_hz, specs, cfg.bfp_group)
    fwd, bwd = simulate_training_iteration(blocks, R, bits)
    total_time = fwd.total_time + bwd.total_time
    # gradient ops (U1a/U1w/U2a/U2w); the reversible arm also pays the
    # eq-2 input recompute (the paper's accepted overhead, §III)
    macs = sum(s.macs for s in specs) + sum(
        b.f1.macs_out * 2 + b.f2.macs_out * 2 for b in blocks)
    if reversible:
        macs += sum(b.f1.macs_out + b.f2.macs_out for b in blocks)

    # weight-stationary dataflow streams the mini-batch sample-by-sample
    # (Fig 17a): a tensor's eDRAM lifetime is its PER-SAMPLE producer→consumer
    # distance, not the whole-batch op time (this is how the paper fits
    # batch-48 training under a 3.4 µs retention, Fig 23a).
    batch = max(blocks[0].f1.batch, 1)

    read_bits = fwd.read_bits + bwd.read_bits
    write_bits = fwd.write_bits + bwd.write_bits
    if reversible:
        max_life = max(fwd.max_lifetime, bwd.max_lifetime) / batch
        stored = max(fwd.peak_live_bits, bwd.peak_live_bits)
        offchip = 0.0
    else:
        # irreversible: every block's activations live until backward
        per_layer = [b.f1.batch * b.f1.c_out * b.f1.width * b.f1.height * bits
                     * 2 for b in blocks]
        stored = max(fwd.peak_live_bits, bwd.peak_live_bits) + sum(per_layer)
        max_life = total_time / batch
        offchip = max(0.0, stored - cfg.onchip_bits) * 2

    controller = None
    stall_s = 0.0
    scalar_memory_j = 0.0
    if cfg.use_edram:
        rf = ed.refresh_free(max_life, cfg.temp_c)
        mem = ed.edram_energy(cfg.edram, read_bits, write_bits, stored,
                              total_time, cfg.temp_c, needs_refresh=not rf)
        scalar_memory_j = mem.total_j
        if cfg.use_controller and reversible:
            # the trace encodes the reversible computation pattern; the
            # irreversible arm's whole-iteration buffering stays scalar
            events, durations, t_total = mtr.merge_traces(fwd, bwd)
            controller = mtr.replay(
                events, cfg.edram, temp_c=cfg.temp_c, duration_s=t_total,
                refresh_policy=cfg.refresh_policy,
                alloc_policy=cfg.alloc_policy, freq_hz=cfg.freq_hz,
                sample_scale=batch, op_durations=durations)
            mem = controller.energy
            stall_s = controller.stall_s
            offchip = controller.offchip_bits
            # report the bank-level verdict, not the scalar one: the
            # iteration is refresh-free iff no bank actually refreshed and
            # no over-retention bank was left unrefreshed (data loss)
            rf = (not any(b.refreshed for b in controller.banks)
                  and controller.safe)
    else:
        rf = True
        mem = ed.sram_energy(cfg.edram, read_bits, write_bits, offchip)

    compute_j = macs * (cfg.mac_pj if cfg.use_edram else cfg.mac_pj_fp16) \
        * 1e-12
    return IterationReport(
        latency_s=total_time + stall_s
        + (offchip / cfg.offchip_bw_bps if offchip else 0.0),
        energy_j=compute_j + mem.total_j,
        compute_j=compute_j,
        memory_j=mem.total_j,
        max_lifetime_s=max_life,
        refresh_free=rf,
        peak_live_bits=stored,
        offchip_bits=offchip,
        controller=controller,
        scalar_memory_j=scalar_memory_j,
        stall_s=stall_s,
    )


def tta_eta(cfg: SystemConfig, blocks: Sequence[DuBlockSpec],
            iterations_to_target: float, reversible: bool = True):
    """Time/Energy-to-Accuracy (§VI-F): per-iteration cost × iterations."""
    rep = iteration(cfg, blocks, reversible)
    return {
        "tta_s": rep.latency_s * iterations_to_target,
        "eta_j": rep.energy_j * iterations_to_target,
        "iteration": rep,
    }
