"""Reversible block stacks with O(1) activation storage (CAMEL §II-C, §III).

A reversible block (RevNet, Gomez et al.) computes

    y2 = x2 + F1(x1)        y1 = x1 + F2(y2)            (eq 1)

and its inputs are recoverable from its outputs:

    x1 = y1 − F2(y2)        x2 = y2 − F1(x1)            (eq 2)

``ReversibleStack`` runs L such blocks under ``lax.scan`` and registers a
``jax.custom_vjp`` whose backward pass *recomputes* every block input from the
stack outputs while walking the stack in reverse — so the compiled training
step stores only the final ``(y1, y2)`` pair (plus the tiny pooled duplex
taps), not L intermediate activations.  This is the paper's data-lifetime /
memory mechanism, and on TPU it is what shrinks the XLA buffer assignment
(``compiled.memory_analysis()``) from O(L) to O(1) residuals.

The backward walk is also the *schedule* of Fig 15: the recompute of
``x1/x2`` (eq 2), the block VJP, and the gradient carries correspond to
``U₂ᵃ/U₁ᵃ/U₂ʷ/U₁ʷ`` with dead intermediates overwritten as the scan carry.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

# F1/F2 signature: (params, x) -> y with y.shape == x.shape.
ApplyFn = Callable[[Any, jax.Array], jax.Array]


class ReversibleStack:
    """A scan-of-reversible-blocks with memory-O(1) custom backward.

    Parameters are a pytree whose leaves are stacked on a leading ``L`` axis
    (one slice per block), holding sub-trees ``f1`` and ``f2``.  An optional
    injection stream ``inj`` (leading axis ``L``, broadcastable to ``x2``)
    is added to ``x2`` before each block — this carries the pooled backbone
    taps of the Duplex architecture (§III-B); its gradient is returned so the
    tap projections train too.
    """

    def __init__(self, f1: ApplyFn, f2: ApplyFn):
        self.f1 = f1
        self.f2 = f2

        @jax.custom_vjp
        def _apply(params, x1, x2, inj):
            (y1, y2), _ = lax.scan(self._fwd_body, (x1, x2), (params, inj))
            return y1, y2

        def _apply_fwd(params, x1, x2, inj):
            out = _apply(params, x1, x2, inj)
            # Residuals: ONLY the stack outputs + params/taps. No per-block
            # activations are saved — they are recomputed in _apply_bwd.
            return out, (params, inj, out[0], out[1])

        def _apply_bwd(res, g):
            params, inj, y1, y2 = res
            g1, g2 = g

            def body(carry, xs):
                y1, y2, g1, g2 = carry
                p, z = xs
                # eq 2 — recompute the block inputs from its outputs.
                x1 = y1 - self.f2(p["f2"], y2)
                x2_mid = y2 - self.f1(p["f1"], x1)  # == x2 + z
                x2 = x2_mid - z

                def block(p_, x1_, x2_, z_):
                    x2m = x2_ + z_
                    y2_ = x2m + self.f1(p_["f1"], x1_)
                    y1_ = x1_ + self.f2(p_["f2"], y2_)
                    return y1_, y2_

                _, vjp = jax.vjp(block, p, x1, x2, z)
                gp, gx1, gx2, gz = vjp((g1, g2))
                return (x1, x2, gx1, gx2), (gp, gz)

            (_, _, gx1, gx2), (gparams, ginj) = lax.scan(
                body, (y1, y2, g1, g2), (params, inj), reverse=True)
            return gparams, gx1, gx2, ginj

        _apply.defvjp(_apply_fwd, _apply_bwd)
        self._apply = _apply

    def _fwd_body(self, carry, xs):
        x1, x2 = carry
        p, z = xs
        x2 = x2 + z                      # duplex tap injection
        y2 = x2 + self.f1(p["f1"], x1)   # eq 1
        y1 = x1 + self.f2(p["f2"], y2)
        return (y1, y2), None

    def __call__(self, params, x1: jax.Array, x2: jax.Array,
                 inj: Optional[jax.Array] = None):
        if inj is None:
            n_blocks = jax.tree_util.tree_leaves(params)[0].shape[0]
            inj = jnp.zeros((n_blocks,) + (1,) * x2.ndim, x2.dtype)
        return self._apply(params, x1, x2, inj)

    def forward_only(self, params, x1, x2, inj=None):
        """Inference path (no vjp registration overhead)."""
        if inj is None:
            n_blocks = jax.tree_util.tree_leaves(params)[0].shape[0]
            inj = jnp.zeros((n_blocks,) + (1,) * x2.ndim, x2.dtype)
        (y1, y2), _ = lax.scan(self._fwd_body, (x1, x2), (params, inj))
        return y1, y2

    def invert(self, params, y1, y2, inj=None):
        """Recover stack inputs from outputs (eq 2) — used by tests and by
        the lifetime analyzer to emit the backward schedule."""
        if inj is None:
            n_blocks = jax.tree_util.tree_leaves(params)[0].shape[0]
            inj = jnp.zeros((n_blocks,) + (1,) * y2.ndim, y2.dtype)

        def body(carry, xs):
            y1, y2 = carry
            p, z = xs
            x1 = y1 - self.f2(p["f2"], y2)
            x2 = y2 - self.f1(p["f1"], x1) - z
            return (x1, x2), None

        (x1, x2), _ = lax.scan(body, (y1, y2), (params, inj), reverse=True)
        return x1, x2


def stack_params(init_fn: Callable[[jax.Array], Any], key: jax.Array,
                 n_blocks: int) -> Any:
    """Initialize L block param trees stacked on a leading axis (scan layout)."""
    keys = jax.random.split(key, n_blocks)
    return jax.vmap(init_fn)(keys)
