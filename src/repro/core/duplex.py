"""Duplex DNN (DuDNN) — CAMEL §III: frozen backbone + reversible branch.

Structure (paper Fig 8/9, generalized from CNN/ViT classification to the
LM-family backbones this framework ships):

* the **backbone** (any registry architecture) runs forward-only under
  ``stop_gradient`` — its weights are frozen, its normalization stays (and is
  statically foldable since it never trains);
* the **branch** is a stack of reversible blocks (``core.reversible``) over a
  *pooled* stream (paper §III-C: aggressive pooling, factor ~16, cuts branch
  compute quadratically) with **no normalization layers** (§III-D) and
  **2D-BFP quantized matmuls** (§III-E);
* backbone hidden states are *tapped* at matching depths, pooled, projected,
  and injected into the branch's ``x2`` stream (knowledge transfer).

LM-causality note (an adaptation the paper didn't need): pooling mixes a
segment's future tokens, so the branch correction for token ``t`` uses only
*fully-past* segments (``floor(t/r) − 1``) and branch attention is causal in
pooled positions.  This keeps next-token training leak-free; see
``upsample_causal``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.reversible import ReversibleStack, stack_params
from repro.models import layers as L
from repro.utils import ceil_to, split_keys


@dataclasses.dataclass(frozen=True)
class DuplexConfig:
    n_blocks: int = 4            # reversible branch depth (paper: 4–6)
    d_branch: int = 256          # branch stream width
    pool_factor: int = 16        # §III-C; paper uses up to 16
    branch_heads: int = 4
    branch_ff_mult: int = 4
    use_norm: bool = False       # §III-D ablation (Fig 21b): default norm-free
    causal: bool = True          # LM mode; False for classification
    bfp: L.BFPPolicy = L.BFPPolicy(enabled=True)  # §III-E on branch matmuls


# --------------------------------------------------------------------------
# pooling / upsampling (seq-dim analogue of the paper's spatial pooling)
# --------------------------------------------------------------------------

def pool_seq(x: jax.Array, r: int) -> jax.Array:
    """Non-overlapping mean pooling along seq: [B,S,D] → [B,ceil(S/r),D]."""
    if r == 1:
        return x
    b, s, d = x.shape
    sp = ceil_to(s, r)
    if sp != s:
        x = jnp.pad(x, ((0, 0), (0, sp - s), (0, 0)))
        # renormalize the ragged tail so padding doesn't dilute the mean
        counts = jnp.clip(jnp.minimum(r, s - jnp.arange(0, sp, r)), 1, r)
    else:
        counts = jnp.full((sp // r,), r)
    pooled = x.reshape(b, sp // r, r, d).sum(axis=2)
    return pooled / counts[None, :, None].astype(x.dtype)


def upsample_causal(y: jax.Array, r: int, s: int) -> jax.Array:
    """Causal upsample: token t receives pooled segment floor(t/r) − 1.

    Segment i pools tokens [i·r, (i+1)·r); only *complete, strictly past*
    segments may influence a token's correction (no label leak).
    """
    if r == 1:
        # even at r=1 a one-step shift is required for strict causality of
        # the *additive correction* path (token t's correction from segment
        # t would include token t itself — fine for LM hidden states, but we
        # keep the shifted convention uniform).
        seg = jnp.arange(s)
    else:
        seg = jnp.arange(s) // r
    idx = jnp.clip(seg - 1, 0, y.shape[1] - 1)
    gathered = y[:, idx]                               # [B,S,D]
    valid = (seg >= 1)[None, :, None]
    return jnp.where(valid, gathered, jnp.zeros_like(gathered))


def upsample_full(y: jax.Array, r: int, s: int) -> jax.Array:
    """Non-causal upsample (classification mode): repeat each segment."""
    idx = jnp.clip(jnp.arange(s) // r, 0, y.shape[1] - 1)
    return y[:, idx]


# --------------------------------------------------------------------------
# branch blocks: F1 = attention mixer, F2 = gated MLP — both norm-free
# --------------------------------------------------------------------------

def _branch_attn_cfg(cfg: DuplexConfig) -> L.AttnConfig:
    hd = max(cfg.d_branch // cfg.branch_heads, 8)
    return L.AttnConfig(
        d_model=cfg.d_branch, n_heads=cfg.branch_heads,
        n_kv=cfg.branch_heads, head_dim=hd, causal=cfg.causal,
        blockwise_threshold=4096)


def branch_block_init(key: jax.Array, cfg: DuplexConfig) -> dict:
    ks = split_keys(key, ["attn", "mlp", "n1", "n2"])
    acfg = _branch_attn_cfg(cfg)
    p = {
        "f1": {"attn": L.attn_init(ks["attn"], acfg)},
        "f2": {"mlp": L.mlp_init(ks["mlp"], cfg.d_branch,
                                 cfg.d_branch * cfg.branch_ff_mult)},
    }
    # norm-free stability: damp the residual writers (out projections)
    p["f1"]["attn"]["wo"]["w"] = p["f1"]["attn"]["wo"]["w"] * 0.1
    p["f2"]["mlp"]["wo"]["w"] = p["f2"]["mlp"]["wo"]["w"] * 0.1
    if cfg.use_norm:
        p["f1"]["norm"] = L.rmsnorm_init(cfg.d_branch)
        p["f2"]["norm"] = L.rmsnorm_init(cfg.d_branch)
    return p


def make_branch_fns(cfg: DuplexConfig, policy: L.Policy):
    acfg = _branch_attn_cfg(cfg)

    def f1(p, x):
        h = L.rmsnorm(p["norm"], x) if cfg.use_norm else x
        return L.attention_layer(p["attn"], h, acfg, policy=policy,
                                 bfp=cfg.bfp)

    def f2(p, x):
        h = L.rmsnorm(p["norm"], x) if cfg.use_norm else x
        return L.mlp(p["mlp"], h, policy=policy, bfp=cfg.bfp)

    return f1, f2


# --------------------------------------------------------------------------
# the duplex branch head: taps in, correction out
# --------------------------------------------------------------------------

def duplex_init(key: jax.Array, cfg: DuplexConfig, d_model: int) -> dict:
    ks = split_keys(key, ["in1", "in2", "taps", "out", "blocks"])
    return {
        "in_proj1": L.dense_init(ks["in1"], d_model, cfg.d_branch),
        "in_proj2": L.dense_init(ks["in2"], d_model, cfg.d_branch),
        # one tap projection per reversible block (stacked for scan)
        "tap_proj": stack_params(
            lambda k: L.dense_init(k, d_model, cfg.d_branch, scale=0.02),
            ks["taps"], cfg.n_blocks),
        "out_proj": L.dense_init(ks["out"], 2 * cfg.d_branch, d_model,
                                 scale=0.02),
        "blocks": stack_params(lambda k: branch_block_init(k, cfg),
                               ks["blocks"], cfg.n_blocks),
    }


def duplex_apply(
    params: dict,
    cfg: DuplexConfig,
    emb: jax.Array,            # [B,S,d_model] frozen input embeddings
    taps: jax.Array,           # [n_blocks,B,S,d_model] frozen backbone taps
    *,
    policy: L.Policy = L.Policy(),
    taps_pooled: bool = False,  # taps already pooled inside the backbone scan
) -> jax.Array:
    """Branch forward: returns the additive correction [B,S,d_model].

    Everything upstream (emb, taps) is stop-gradient'ed — the backbone is
    frozen (paper Fig 9b/c) and XLA stores no residuals for it.
    """
    b, s, d_model = emb.shape
    r = cfg.pool_factor
    emb = jax.lax.stop_gradient(emb)
    taps = jax.lax.stop_gradient(taps)

    pooled_in = pool_seq(emb, r)                        # [B,Sp,D]
    pooled_taps = taps if taps_pooled else \
        jax.vmap(lambda t: pool_seq(t, r))(taps)        # [L,B,Sp,D]

    f1, f2 = make_branch_fns(cfg, policy)
    stack = ReversibleStack(f1, f2)

    x1 = L.dense(params["in_proj1"], pooled_in, policy=policy, bfp=cfg.bfp)
    x2 = L.dense(params["in_proj2"], pooled_in, policy=policy, bfp=cfg.bfp)
    inj = jax.vmap(
        lambda p, t: L.dense(p, t, policy=policy, bfp=cfg.bfp)
    )(params["tap_proj"], pooled_taps)                  # [L,B,Sp,d_branch]

    y1, y2 = stack(params["blocks"], x1, x2, inj)
    y = jnp.concatenate([y1, y2], axis=-1)              # [B,Sp,2·d_branch]
    corr = L.dense(params["out_proj"], y, policy=policy, bfp=cfg.bfp)
    up = upsample_causal if cfg.causal else upsample_full
    return up(corr, r, s)
