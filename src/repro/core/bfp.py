"""2D Block Floating-Point (BFP) quantization — CAMEL §III-E.

A matrix is tiled into *square* 2D groups; each group shares one exponent and
keeps per-element signed mantissas.  Squareness is the paper's point: it makes
quantization commute with transposition, ``Q(Wᵀ) = Q(W)ᵀ``, so the backward
pass (which needs ``Wᵀ`` and ``Aᵀ``, Table I) never re-quantizes.

Paper-faithful format: 3×3 groups, 4-bit shared exponent, 1-bit sign + 5-bit
mantissa  ⇒  58 bits / 9 values = 6.4 bits/value.

TPU-native format (this framework's default for kernels): 32×32 or larger
square groups aligned with the MXU 128×128 tile — the same transpose
invariance holds for any square group (see DESIGN.md §2).

This module is the **pure-jnp reference**; ``repro.kernels`` holds the Pallas
TPU kernels validated against it.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.utils import ceil_to

# Paper constants (Section III-E).
PAPER_GROUP: Tuple[int, int] = (3, 3)
PAPER_EBITS: int = 4
PAPER_MBITS: int = 5  # magnitude bits; sign is separate.

# TPU-native default: square group aligned to MXU/VREG tiling.
TPU_GROUP: Tuple[int, int] = (32, 32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BFPTensor:
    """A 2D-BFP-quantized matrix (last two dims grouped).

    ``mant``  int8  — signed mantissas, shape ``padded_shape``.
    ``exp``   int8  — shared exponents, one per group:
                      ``padded_shape[:-2] + (Mp/g1, Np/g2)``.
    """

    mant: jax.Array
    exp: jax.Array
    shape: Tuple[int, ...]        # logical (unpadded) shape
    group: Tuple[int, int]
    mbits: int

    def tree_flatten(self):
        return (self.mant, self.exp), (self.shape, self.group, self.mbits)

    @classmethod
    def tree_unflatten(cls, aux, children):
        mant, exp = children
        shape, group, mbits = aux
        return cls(mant, exp, shape, group, mbits)

    @property
    def transpose(self) -> "BFPTensor":
        """Q(Wᵀ) = Q(W)ᵀ — the paper's transpose invariance (Fig 11)."""
        g1, g2 = self.group
        swap = lambda a: jnp.swapaxes(a, -1, -2)
        return BFPTensor(
            mant=swap(self.mant),
            exp=swap(self.exp),
            shape=self.shape[:-2] + (self.shape[-1], self.shape[-2]),
            group=(g2, g1),
            mbits=self.mbits,
        )

    @property
    def bits_per_value(self) -> float:
        g1, g2 = self.group
        return (g1 * g2 * (1 + self.mbits) + PAPER_EBITS) / (g1 * g2)


def _floor_exponent(amax: jax.Array) -> jax.Array:
    """floor(log2(amax)) as int32; 0 → large negative (group of zeros)."""
    _, e = jnp.frexp(amax)          # amax = m * 2^e with m in [0.5, 1)
    e = e - 1                        # floor(log2 amax)
    return jnp.where(amax > 0, e, jnp.full_like(e, -127)).astype(jnp.int32)


def _pad2d(x: jax.Array, group: Tuple[int, int]) -> jax.Array:
    g1, g2 = group
    m, n = x.shape[-2:]
    mp, np_ = ceil_to(m, g1), ceil_to(n, g2)
    if (mp, np_) == (m, n):
        return x
    pads = [(0, 0)] * (x.ndim - 2) + [(0, mp - m), (0, np_ - n)]
    return jnp.pad(x, pads)


def bfp_quantize(
    x: jax.Array,
    group: Tuple[int, int] = PAPER_GROUP,
    ebits: int = PAPER_EBITS,
    mbits: int = PAPER_MBITS,
) -> BFPTensor:
    """Quantize the last two dims of ``x`` into 2D BFP groups (Fig 10)."""
    if x.ndim < 2:
        raise ValueError(f"BFP needs >=2 dims, got shape {x.shape}")
    g1, g2 = group
    orig_shape = x.shape
    xp = _pad2d(x.astype(jnp.float32), group)
    *lead, mp, np_ = xp.shape
    xg = xp.reshape(*lead, mp // g1, g1, np_ // g2, g2)

    amax = jnp.max(jnp.abs(xg), axis=(-3, -1), keepdims=True)
    e = _floor_exponent(amax)
    emin, emax = -(2 ** (ebits - 1)), 2 ** (ebits - 1) - 1
    e = jnp.clip(e, emin, emax)

    # scale so the largest element maps near the top of the mantissa range
    scale = jnp.exp2((e - (mbits - 1)).astype(jnp.float32))
    lim = 2**mbits - 1
    m = jnp.clip(jnp.round(xg / scale), -lim, lim).astype(jnp.int8)

    mant = m.reshape(*lead, mp, np_)
    exp = e.squeeze((-3, -1)).astype(jnp.int8)
    return BFPTensor(mant=mant, exp=exp, shape=orig_shape, group=group, mbits=mbits)


def bfp_dequantize(t: BFPTensor, dtype=jnp.float32) -> jax.Array:
    g1, g2 = t.group
    *lead, mp, np_ = t.mant.shape
    mg = t.mant.reshape(*lead, mp // g1, g1, np_ // g2, g2).astype(jnp.float32)
    e = t.exp.astype(jnp.float32)[..., :, None, :, None]
    scale = jnp.exp2(e - (t.mbits - 1))
    xg = mg * scale
    x = xg.reshape(*lead, mp, np_)
    m, n = t.shape[-2:]
    return x[..., :m, :n].astype(dtype)


def _qdq(x, group, ebits, mbits):
    return bfp_dequantize(bfp_quantize(x, group, ebits, mbits), dtype=x.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def bfp_qdq(x: jax.Array,
            group: Tuple[int, int] = PAPER_GROUP,
            ebits: int = PAPER_EBITS,
            mbits: int = PAPER_MBITS) -> jax.Array:
    """Fake-quantize (quantize→dequantize) with a straight-through gradient.

    This is how BFP training is injected into matmuls: operands pass through
    ``bfp_qdq`` in the forward pass; the backward pass sees identity (the
    standard STE used by the BFP-training literature the paper builds on).
    """
    return _qdq(x, group, ebits, mbits)


def _qdq_fwd(x, group, ebits, mbits):
    return _qdq(x, group, ebits, mbits), None


def _qdq_bwd(group, ebits, mbits, res, g):
    del group, ebits, mbits, res
    return (g,)


bfp_qdq.defvjp(_qdq_fwd, _qdq_bwd)


def bfp_matmul_ref(
    a: jax.Array,
    b: jax.Array,
    group: Tuple[int, int] = PAPER_GROUP,
    ebits: int = PAPER_EBITS,
    mbits: int = PAPER_MBITS,
    precision=None,
) -> jax.Array:
    """Reference BFP matmul: quantize both operands, multiply in f32.

    Matches the PE-array semantics (Fig 5): within a group pair, mantissas
    multiply-accumulate in fixed point and exponents add once — numerically
    identical to dequantize-then-multiply in f32, which is what we do here.
    """
    aq = _qdq(a.astype(jnp.float32), group, ebits, mbits)
    bq = _qdq(b.astype(jnp.float32), group, ebits, mbits)
    return jnp.matmul(aq, bq, precision=precision)


def quantization_rmse(x: jax.Array, **kw) -> jax.Array:
    """RMS error of the BFP round-trip — used by fidelity benchmarks."""
    y = _qdq(x.astype(jnp.float32), kw.get("group", PAPER_GROUP),
             kw.get("ebits", PAPER_EBITS), kw.get("mbits", PAPER_MBITS))
    return jnp.sqrt(jnp.mean((x - y) ** 2))
