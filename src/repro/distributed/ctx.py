"""Activation-sharding context: models call ``constrain(x, name)`` at
strategic tensors; a launcher installs per-arch PartitionSpec rules.  When no
rules are installed (CPU unit tests) the calls are no-ops, so model code
stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None
_RULES: Optional[dict] = None


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: dict):
    """rules: {name: PartitionSpec} — installed for the duration."""
    global _MESH, _RULES
    prev = (_MESH, _RULES)
    _MESH, _RULES = mesh, rules
    try:
        yield
    finally:
        _MESH, _RULES = prev


def constrain(x: jax.Array, name: str) -> jax.Array:
    if _RULES is None or name not in _RULES:
        return x
    spec = _RULES[name]
    if len(spec) > x.ndim:          # rank-adjust (e.g. decode S=1 collapsed)
        spec = P(*spec[:x.ndim])
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
