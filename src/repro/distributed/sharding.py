"""Sharding rules: param/optimizer/cache/batch pytrees → PartitionSpecs.

Scheme (DESIGN.md §6):
* ``model`` axis — tensor parallel (attention heads / MLP hidden / experts /
  vocab) + sequence-sharded KV caches for serving;
* ``data`` axis — batch DP + FSDP weight sharding (ZeRO-3-style: the
  non-TP dim of every large weight is sharded over ``data`` and gathered at
  use);
* ``pod`` axis — pure DP across pods: weights replicated, only gradients
  cross the inter-pod links (under the duplex regime those are just the tiny
  branch gradients — the paper's structure paying off at pod scale).

Every rule is divisibility-guarded: if a dim doesn't divide its mesh axis,
that dim falls back to replication (e.g. 36 or 40 attention heads on TP=16
⇒ the head axis replicates and attention runs sequence-parallel instead).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils import path_str

# (pattern, spec template applied to the *logical* (unstacked) shape)
# first match wins; "data"/"model" are mesh axes, None replicates.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/table$", ("model", "data")),
    (r"attn/w[qkv]/w$", ("data", "model")),
    (r"attn/w[qkv]/b$", ("model",)),
    (r"attn/wo/w$", ("model", "data")),
    (r"moe/router/w$", (None, None)),
    (r"moe/w[ig]$", ("model", "data", None)),
    (r"moe/wo$", ("model", None, "data")),
    (r"(mlp|shared)/w[ig]/w$", ("data", "model")),
    (r"(mlp|shared)/wo/w$", ("model", "data")),
    (r"ssd/(z|x|dt)_proj/w$", ("data", "model")),
    (r"ssd/(b|c)_proj/w$", ("data", None)),
    (r"ssd/out_proj/w$", ("model", "data")),
    (r"ssd/conv_x/w$", (None, "model")),
    (r"ssd/conv_x/b$", ("model",)),
    (r"ssd/conv_[bc]/", (None,)),          # tiny B/C convs: replicate
    (r"ssd/(dt_bias|A_log|D)$", ("model",)),
    (r"ssd/norm/scale$", ("model",)),      # rmsnorm over sharded d_inner
    (r"lru/w[xy]/w$", ("data", "model")),
    (r"lru/wo/w$", ("model", "data")),
    (r"lru/w[ri]/w$", ("model", None)),
    (r"lru/w[ri]/b$", (None,)),
    (r"lru/conv_w$", (None, "model")),
    (r"lru/(conv_b|lambda)$", ("model",)),
    # duplex branch projections follow the generic dense rules below
    (r"(in_proj[12]|out_proj|tap_proj)/w$", ("data", "model")),
    # norms / everything else: replicated
    (r".*", ()),
]

_STACKED_PREFIXES = ("stack/", "blocks/", "tap_proj/")


def _mesh_axis_size(mesh: Mesh, axis: Optional[str]) -> int:
    return 1 if axis is None else mesh.shape[axis]


def _guard(spec: tuple, shape: tuple, mesh: Mesh) -> tuple:
    out = []
    for i, ax in enumerate(spec):
        if ax is not None and shape[i] % _mesh_axis_size(mesh, ax) != 0:
            ax = None
        out.append(ax)
    return tuple(out)


def _is_stacked(path: str) -> bool:
    return any(s in path for s in _STACKED_PREFIXES)


def param_pspec(path: str, shape: tuple, mesh: Mesh, *,
                fsdp_pure: bool = False,
                lru_gates_colparallel: bool = False) -> P:
    """Param rules with two §Perf variants:

    * ``fsdp_pure`` — shard dim-0 of every large weight over the *combined*
      (data, model) axes and replicate nothing else (ZeRO-3).  For the
      frozen duplex backbone this removes every per-layer TP psum of the
      residual stream; weights are all-gathered once per layer, forward
      only (no backward re-gather — the backbone has no gradients).
    * ``lru_gates_colparallel`` — RG-LRU gates W_r/W_i switch from
      row-parallel (full-width psum of [B,S,W] per gate per layer) to
      column-parallel (one [B,S,W] all-gather of the shared input).
    """
    lead = 1 if (_is_stacked(path) and len(shape) >= 1) else 0
    logical = shape[lead:]
    if fsdp_pure and len(logical) >= 2:
        combined = tuple(a for a in ("data", "model")
                         if a in mesh.axis_names)
        n = 1
        for a in combined:
            n *= mesh.shape[a]
        spec = [None] * len(logical)
        placed = False
        for d in range(len(logical)):          # prefer a fully-sharded dim
            if logical[d] % n == 0:
                spec[d] = combined
                placed = True
                break
        if not placed:
            # split the axes across two dims (e.g. 29568×8192 on 16×16)
            ax0, ax1 = combined if len(combined) == 2 else (combined[0],) * 2
            if logical[0] % mesh.shape[ax0] == 0 and \
                    logical[1] % mesh.shape[ax1] == 0:
                spec[0], spec[1] = ax0, ax1
            elif logical[0] % mesh.shape[ax0] == 0:
                spec[0] = ax0
            elif logical[1] % mesh.shape[ax1] == 0:
                spec[1] = ax1
        return P(*((None,) * lead + tuple(spec)))
    rules = _PARAM_RULES
    if lru_gates_colparallel:
        rules = [(r"lru/w[ri]/w$", (None, "model")),
                 (r"lru/w[ri]/b$", ("model",))] + rules
    for pat, spec in rules:
        if re.search(pat, path):
            spec = spec[:len(logical)]
            spec = spec + (None,) * (len(logical) - len(spec))
            spec = _guard(spec, logical, mesh)
            return P(*((None,) * lead + spec))
    return P()


def dp_axes(mesh: Mesh, include_model: bool = False):
    names = ("pod", "data", "model") if include_model else ("pod", "data")
    axes = tuple(a for a in mesh.axis_names if a in names)
    return axes if len(axes) > 1 else axes[0]


def _guard_dp(batch_dim: int, mesh: Mesh,
              include_model: bool = False) -> Optional[Any]:
    names = ("pod", "data", "model") if include_model else ("pod", "data")
    total = 1
    for a in names:
        if a in mesh.axis_names:
            total *= mesh.shape[a]
    return dp_axes(mesh, include_model) if batch_dim % total == 0 else None


def cache_pspec(path: str, shape: tuple, mesh: Mesh) -> P:
    """KV caches / recurrent states: batch over DP, seq-or-state over model."""
    lead = 1 if path.startswith("stack/") else 0
    logical = shape[lead:]
    name = path.rsplit("/", 1)[-1]
    if name in ("len", "step") or not logical:
        return P()
    if name == "pos":
        return P(*((None,) * len(shape)))
    dp = _guard_dp(logical[0], mesh)
    spec: tuple
    if name in ("k", "v"):
        # [B, S, KV, hd] — sequence-sharded cache (context parallelism)
        spec = (dp, "model", None, None)
    elif name == "h" and len(logical) == 4:       # ssd [B,H,P,N]
        spec = (dp, "model", None, None)
    elif name == "h" and len(logical) == 2:       # lru [B,W]
        spec = (dp, "model")
    elif name.startswith("conv"):                 # [B,K-1,C]
        spec = (dp, None, "model")
    else:
        spec = (dp,) + (None,) * (len(logical) - 1)
    spec = spec[:len(logical)] + (None,) * (len(logical) - len(spec))
    guarded = []
    for i, s in enumerate(spec):
        if s is None or s == dp or isinstance(s, tuple):
            guarded.append(s)          # dp already divisibility-guarded
        else:
            guarded.append(s if logical[i] % mesh.shape[s] == 0 else None)
    return P(*((None,) * lead + tuple(guarded)))


def batch_pspec(shape: tuple, mesh: Mesh,
                include_model: bool = False) -> P:
    """``include_model=True``: batch over ALL axes (the fsdp_pure layout)."""
    dp = _guard_dp(shape[0], mesh, include_model)
    if include_model and dp is None:
        dp = _guard_dp(shape[0], mesh)      # fall back to pod×data
    return P(*((dp,) + (None,) * (len(shape) - 1)))


# --------------------------------------------------------------------------
# tree-level helpers
# --------------------------------------------------------------------------

def tree_pspecs(tree: Any, mesh: Mesh, rule) -> Any:
    """Map a pytree of arrays/ShapeDtypeStructs to PartitionSpecs."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: rule(_strip(path_str(p)), x.shape, mesh), tree)


def _strip(path: str) -> str:
    # optimizer state wraps the param tree under mu/nu; strip for matching
    for pre in ("mu/", "nu/", "backbone/", "branch/", "opt/"):
        if path.startswith(pre):
            return _strip(path[len(pre):])
    return path


def state_pspecs(state_shapes: Any, mesh: Mesh, pspec=None) -> Any:
    pspec = pspec or param_pspec
    def rule(path, shape, m):
        if path in ("step",) or path.endswith("/step") or not shape:
            return P()
        return pspec(path, shape, m)
    return tree_pspecs(state_shapes, mesh, rule)


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
