"""repro.distributed"""
