#!/usr/bin/env python3
"""CI gate for timeline-replay throughput.

Usage: check_replay_bench.py FRESH_JSON [--record BENCH_replay.json]
                             [--floor 0.7]

FRESH_JSON is a ``python -m benchmarks.replay_throughput --json`` dump
from the current checkout.  For every mode (granularity + backend +
traced combination, e.g. ``row+vector``) present in *both* the fresh
run and the committed trajectory file, the fresh ``ops_per_s`` must be
at least ``--floor`` (default 0.7) times the **best** committed record
for that mode — so a PR can be a little slower than the best day ever
measured (CI machines are noisy) but a real regression fails the gate.

Modes with no committed record yet (a new backend, a new trace row)
pass with a note; commit a ``--update`` record to start gating them.
"""
import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_RECORD = REPO / "BENCH_replay.json"


def mode_tag(m: dict) -> str:
    # mirrors benchmarks.replay_throughput.mode_tag (kept standalone so
    # the tool runs without PYTHONPATH=src)
    return (m["granularity"]
            + ("+vector" if m.get("backend") == "vector" else "")
            + ("+trace" if m.get("traced") else "")
            + ("+tiered" if m.get("tiered") else ""))


def best_committed(record_path: pathlib.Path) -> dict:
    """mode tag -> best committed ops_per_s across all records."""
    data = json.loads(record_path.read_text())
    best: dict = {}
    for rec in data.get("records", []):
        for m in rec.get("measurements", []):
            tag = mode_tag(m)
            best[tag] = max(best.get(tag, 0.0), m["ops_per_s"])
    return best


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", type=pathlib.Path,
                    help="fresh measurement dump (--json output)")
    ap.add_argument("--record", type=pathlib.Path, default=DEFAULT_RECORD,
                    help="committed trajectory file (default: "
                         "BENCH_replay.json at the repo root)")
    ap.add_argument("--floor", type=float, default=0.7,
                    help="minimum fresh/best-committed ratio per mode")
    args = ap.parse_args(argv)

    fresh = json.loads(args.fresh.read_text())
    best = best_committed(args.record)
    if not best:
        print(f"ERROR: no committed records in {args.record}")
        return 1

    failures = 0
    gated = 0
    for m in fresh:
        tag = mode_tag(m)
        got = m["ops_per_s"]
        if tag not in best:
            print(f"note: {tag}  {got:.0f} ops/s  (no committed record "
                  "yet; not gated)")
            continue
        gated += 1
        need = args.floor * best[tag]
        ok = got >= need
        failures += not ok
        print(f"{'ok ' if ok else 'FAIL'}: {tag}  {got:.0f} ops/s  "
              f"(floor {need:.0f} = {args.floor:g}x best committed "
              f"{best[tag]:.0f})")
    if not gated:
        print("ERROR: no fresh measurement matched a committed mode")
        return 1
    if failures:
        print(f"{failures} mode(s) below the throughput floor")
        return 1
    print(f"all {gated} gated mode(s) above the floor")
    return 0


if __name__ == "__main__":
    sys.exit(main())
