"""Markdown link checker for the repo's docs (stdlib only).

Scans the given markdown files for inline links/images
(``[text](target)``) and verifies that every *relative* target exists on
disk (anchors are stripped; ``http(s)://``, ``mailto:`` and pure-anchor
links are skipped).  Exits non-zero listing the broken links.

    python tools/check_links.py README.md ROADMAP.md docs/*.md
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links/images; [text](target "title") tolerated, nested parens not
_LINK = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\s*\)")
_SKIP = ("http://", "https://", "mailto:")


def check(paths: list[str]) -> list[str]:
    broken = []
    for name in paths:
        md = Path(name)
        text = md.read_text(encoding="utf-8")
        # fenced code blocks routinely contain (…) that aren't links
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in _LINK.findall(text):
            if target.startswith(_SKIP) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (md.parent / rel).exists():
                broken.append(f"{md}: broken link -> {target}")
    return broken


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    missing = [p for p in argv if not Path(p).exists()]
    if missing:
        print("no such file: " + ", ".join(missing), file=sys.stderr)
        return 2
    broken = check(argv)
    for line in broken:
        print(line, file=sys.stderr)
    print(f"{len(argv)} files checked, {len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
