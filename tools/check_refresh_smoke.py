#!/usr/bin/env python3
"""CI gate for the row-granularity fig24 smoke run.

Usage: check_refresh_smoke.py BANK_JSON ROW_JSON

Both inputs are ``benchmarks.run --json`` records from fig24 frequency
sweeps — BANK_JSON from the bank-granularity run, ROW_JSON from the
``--granularity row`` run over the same frequencies.  Asserts, per
matching (arm, freq_hz) operating point:

- the row run's ``refresh_stall_s`` is <= the bank run's (row pulses
  interleave with compute at wordline boundaries, so they can only hide
  more), and
- the row run actually refreshed rows wherever the bank run stalled.

Also requires the sweep to include the hot (T100) operating point — the
configuration whose bank-granular pulse exceeds the retention interval.
"""
import json
import sys


def _freq_records(path):
    with open(path) as f:
        records = json.load(f)
    out = {}
    for r in records:
        if r.get("freq_hz") is None or "refresh_stall_s" not in r:
            continue
        if r.get("name", "").endswith("/WARN"):
            continue
        out[(r["arm"], r["freq_hz"])] = r
    return out


def main(bank_path: str, row_path: str) -> int:
    bank = _freq_records(bank_path)
    row = _freq_records(row_path)
    keys = sorted(set(bank) & set(row))
    if not keys:
        print("ERROR: no matching (arm, freq_hz) records between "
              f"{bank_path} and {row_path}")
        return 1
    if not any("T100" in arm for arm, _ in keys):
        print("ERROR: the sweep is missing the hot (T100) operating point")
        return 1
    failures = 0
    for key in keys:
        b, r = bank[key], row[key]
        # ≤ up to float rounding: a fully-preempting tick's row stall is
        # a sum of per-row divisions vs the bank pulse's single division
        ok = r["refresh_stall_s"] <= b["refresh_stall_s"] * (1 + 1e-9) \
            + 1e-18
        if b["refresh_stall_s"] > 0.0:
            ok = ok and r.get("rows_refreshed", 0) > 0
        status = "ok" if ok else "FAIL"
        print(f"{status}: {key[0]} @ {key[1] / 1e6:g} MHz  "
              f"bank_stall={b['refresh_stall_s']:.3e}s  "
              f"row_stall={r['refresh_stall_s']:.3e}s  "
              f"rows={r.get('rows_refreshed', 0)}")
        failures += not ok
    return 1 if failures else 0


if __name__ == "__main__":
    if len(sys.argv) != 3:
        print(__doc__)
        sys.exit(2)
    sys.exit(main(sys.argv[1], sys.argv[2]))
