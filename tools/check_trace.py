#!/usr/bin/env python3
"""CI gate for exported flight-recorder traces.

Usage: check_trace.py TRACE_JSON [TRACE_JSON ...]

Each input is a Chrome Trace Event file written by
``repro.obs.export_chrome_trace`` (e.g. ``benchmarks.run --only fig24
--trace DIR``).  Per file, asserts:

1. **Schema** — the JSON object form (``traceEvents`` list +
   ``displayTimeUnit``), every event a dict with ``ph``/``pid``/
   ``name``, duration events with numeric ``ts``/``dur >= 0``, and
   every span/counter carrying its raw second-domain values in ``args``
   (``t0_s <= t1_s`` / ``t_s``).
2. **Ordering** — non-metadata events sorted by ``ts``.
3. **No overlap** — on every bank's port track and hidden-refresh track,
   and on the array's op track, spans are pairwise disjoint (checked in
   the exact second domain, not the rounded µs one).  The
   ``refresh_stall`` track is exempt: preempting pulses serialize at
   their deadline, so consecutive stalls legitimately stack there.
4. **Reconciliation** — when the file embeds its report
   (``otherData.report``), the rebuilt recorder re-derives ``stall_s`` /
   ``refresh_stall_s`` / ``refresh_hidden_j`` / ``rows_refreshed`` and
   they must match the report *exactly* (``repro.obs.reconcile``).

Exit 0 when every file passes; prints one ``file: ok`` / failure line
per input.
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro.obs.export import recorder_from_trace  # noqa: E402
from repro.obs.reconcile import reconcile  # noqa: E402

# span tracks that must be pairwise disjoint (kind -> why)
DISJOINT_KINDS = ("op", "port", "refresh")


def check_schema(trace: dict) -> list:
    errs = []
    if not isinstance(trace.get("traceEvents"), list):
        return ["traceEvents missing or not a list"]
    if "displayTimeUnit" not in trace:
        errs.append("displayTimeUnit missing")
    last_ts = None
    for i, e in enumerate(trace["traceEvents"]):
        where = f"event[{i}]"
        if not isinstance(e, dict):
            errs.append(f"{where}: not an object")
            continue
        for key in ("ph", "pid", "name"):
            if key not in e:
                errs.append(f"{where}: missing {key!r}")
        ph = e.get("ph")
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)):
            errs.append(f"{where}: non-numeric ts {ts!r}")
            continue
        if last_ts is not None and ts < last_ts:
            errs.append(f"{where}: ts {ts} < previous {last_ts} "
                        f"(events not sorted)")
        last_ts = ts
        args = e.get("args", {})
        if ph == "X":
            if not isinstance(e.get("dur"), (int, float)) or e["dur"] < 0:
                errs.append(f"{where}: X event needs dur >= 0")
            if not ("t0_s" in args and "t1_s" in args
                    and args["t0_s"] <= args["t1_s"]):
                errs.append(f"{where}: raw args t0_s <= t1_s missing")
        elif ph == "C":
            if "t_s" not in args or "value" not in args:
                errs.append(f"{where}: C event needs args t_s/value")
    return errs


def check_overlap(recorder) -> list:
    """Pairwise-disjoint spans per (bank, kind) track, in seconds."""
    errs = []
    tracks: dict = {}
    for s in recorder.spans:
        if s.kind in DISJOINT_KINDS:
            tracks.setdefault((s.bank, s.kind), []).append(s)
    for (bank, kind), spans in sorted(tracks.items()):
        spans = sorted(spans, key=lambda s: (s.t0, s.t1))
        for a, b in zip(spans, spans[1:]):
            if b.t0 < a.t1:
                errs.append(
                    f"overlap on bank={bank} track={kind}: "
                    f"[{a.t0:g},{a.t1:g}) {a.name!r} vs "
                    f"[{b.t0:g},{b.t1:g}) {b.name!r}")
                break                      # one per track is enough signal
    return errs


def check_file(path: str) -> list:
    with open(path) as f:
        trace = json.load(f)
    errs = check_schema(trace)
    if errs:
        return errs
    recorder, report = recorder_from_trace(trace)
    errs += check_overlap(recorder)
    if report is None:
        errs.append("otherData.report missing (nothing to reconcile)")
    elif recorder.meta.get("timing") == "timeline":
        res = reconcile(recorder, report)
        if not res.ok:
            errs += [f"reconcile: {c.field} report={c.reported!r} "
                     f"derived={c.derived!r}" for c in res.failures()]
    return errs


def main(paths) -> int:
    if not paths:
        print("usage: check_trace.py TRACE_JSON [TRACE_JSON ...]")
        return 2
    bad = 0
    for path in paths:
        errs = check_file(path)
        if errs:
            bad += 1
            print(f"{path}: FAIL")
            for e in errs[:10]:
                print(f"  {e}")
        else:
            print(f"{path}: ok")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
