#!/usr/bin/env python3
"""CI gate for the iso-area SRAM:eDRAM tier sweep.

Usage: check_tier_sweep.py FRESH_JSON [--record BENCH_tiers.json]

FRESH_JSON is a ``python -m benchmarks.tier_sweep --json`` dump from the
current checkout.  The gate asserts the physical claims the hybrid-tier
subsystem exists to show (see ``benchmarks/tier_sweep.py``):

- **grid shape** — at least three splits, including both homogeneous
  endpoints (``s=0`` all-eDRAM, ``s=1`` all-SRAM);
- **endpoint delegation** — the ``s=0`` row ran the registered
  ``DuDNN+CAMEL`` arm and the ``s=1`` row the registered ``FR+SRAM``
  arm (``sim.hybrid_arm`` returns the homogeneous arms themselves at
  the endpoints, so they can never drift from the Fig-24 records);
- **iso-area** — every row satisfies ``edram_kb + 2*sram_kb == 384``
  (the stock 12×32 KB array at ``density_vs_sram=2``);
- **monotone leakage** — static tier leakage strictly increases with
  the SRAM share (SRAM cells leak more per kB);
- **refresh dies at s=1** — the all-SRAM endpoint reports exactly zero
  refresh energy and ``refresh_free=true``;
- **interior win** — some interior split's total energy is strictly
  below *both* endpoints;
- **trajectory match** (when ``--record`` exists) — splits present in
  the latest committed record reproduce its energy to 1e-9 relative
  (the sim is deterministic; a drift here means the model changed
  without a ``--update`` record).
"""
import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_RECORD = REPO / "BENCH_tiers.json"

TOTAL_KB = 384.0          # stock eDRAM array: 12 banks x 32 KB
DENSITY_VS_SRAM = 2.0     # eDRAM kB per SRAM kB at equal area


def _check(ok: bool, label: str, detail: str) -> int:
    print(f"{'ok ' if ok else 'FAIL'}: {label}  {detail}")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", type=pathlib.Path,
                    help="fresh sweep dump (--json output)")
    ap.add_argument("--record", type=pathlib.Path, default=DEFAULT_RECORD,
                    help="committed trajectory file (default: "
                         "BENCH_tiers.json at the repo root)")
    args = ap.parse_args(argv)

    ms = sorted(json.loads(args.fresh.read_text())["measurements"],
                key=lambda m: m["split"])
    failures = 0

    splits = [m["split"] for m in ms]
    failures += _check(
        len(ms) >= 3 and splits[0] == 0.0 and splits[-1] == 1.0,
        "grid", f"splits={splits}")

    lo, hi = ms[0], ms[-1]
    failures += _check(lo["arm"] == "DuDNN+CAMEL",
                       "endpoint s=0", f"arm={lo['arm']}")
    failures += _check(hi["arm"] == "FR+SRAM",
                       "endpoint s=1", f"arm={hi['arm']}")

    iso = all(abs(m["edram_kb"] + DENSITY_VS_SRAM * m["sram_kb"]
                  - TOTAL_KB) < 1e-9 for m in ms)
    failures += _check(iso, "iso-area",
                       f"edram_kb + {DENSITY_VS_SRAM:g}*sram_kb == "
                       f"{TOTAL_KB:g} on every row")

    leak = [m["leakage_mw"] for m in ms]
    failures += _check(all(b > a for a, b in zip(leak, leak[1:])),
                       "monotone leakage",
                       "->".join(f"{v:.3f}" for v in leak) + " mW")

    failures += _check(hi["refresh_j"] == 0.0 and hi["refresh_free"],
                       "refresh->0 at s=1",
                       f"refresh_j={hi['refresh_j']:g};"
                       f"refresh_free={hi['refresh_free']}")

    interior = [m for m in ms if 0.0 < m["split"] < 1.0]
    best = min(interior, key=lambda m: m["energy_j"]) if interior else None
    failures += _check(
        best is not None and best["energy_j"] < lo["energy_j"]
        and best["energy_j"] < hi["energy_j"],
        "interior win",
        (f"s{best['split']:g}@{best['energy_j']:.4e}J < "
         f"endpoints {lo['energy_j']:.4e}/{hi['energy_j']:.4e}J"
         if best else "no interior split in the grid"))

    if args.record.exists():
        committed = {m["split"]: m
                     for m in json.loads(args.record.read_text())
                     ["records"][-1]["measurements"]}
        matched = [m for m in ms if m["split"] in committed]
        drift = [m["split"] for m in matched
                 if abs(m["energy_j"] - committed[m["split"]]["energy_j"])
                 > 1e-9 * committed[m["split"]]["energy_j"]]
        failures += _check(bool(matched) and not drift,
                           "trajectory match",
                           f"{len(matched)} split(s) vs latest committed "
                           f"record; drifted={drift}")
    else:
        print(f"note: no committed record at {args.record}; trajectory "
              "check skipped")

    if failures:
        print(f"{failures} tier-sweep check(s) failed")
        return 1
    print("all tier-sweep checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
