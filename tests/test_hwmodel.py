"""eDRAM model, lifetime closed forms vs schedule simulation, TTA/ETA."""
import math

import pytest

from repro import sim
from repro.core import edram as ed, hwmodel as hw, lifetime as lt, schedule as sc


def test_retention_matches_fig22_endpoints():
    assert abs(ed.retention_s(100.0) - 3.4e-6) / 3.4e-6 < 1e-6
    assert abs(ed.retention_s(-30.0) - 30e-6) / 30e-6 < 1e-6
    # monotone decreasing in temperature
    assert ed.retention_s(0) > ed.retention_s(50) > ed.retention_s(100)


def test_refresh_free_criterion():
    assert ed.refresh_free(3.0e-6, 100.0)
    assert not ed.refresh_free(4.0e-6, 100.0)
    assert ed.refresh_margin(3.0e-6, 100.0) > 1.0


def _blocks(n=6, batch=48, spatial=7, cb=64, ck=256):
    return lt.duplex_block_specs(n, batch, spatial, cb, ck)


def test_latencies_eqs_3_5():
    b = _blocks()[0]
    R = 1e12
    assert lt.latency(b.f1.macs, R) == pytest.approx(
        48 * 64 * 7 * 7 * 9 / 1e12)


def test_closed_forms_match_schedule_simulation():
    """eqs 6/9 vs the discrete-event simulator, within one op duration."""
    blocks = _blocks()
    R = 1e12
    fwd_cf = lt.forward_lifetimes(blocks, R)
    bwd_cf = lt.backward_lifetimes(blocks, R)
    fwd, bwd = sc.simulate_training_iteration(blocks, R)

    tol = max(lt.latency(b.g.macs, R) for b in blocks) + \
        2 * max(lt.latency(b.f2.macs, R) for b in blocks)
    cf_max = max(max(max(d.values()) for d in fwd_cf),
                 max(max(d.values()) for d in bwd_cf))
    sim_max = max(fwd.max_lifetime, bwd.max_lifetime)
    assert abs(cf_max - sim_max) <= tol, (cf_max, sim_max, tol)
    assert lt.max_data_lifetime(blocks, R) == pytest.approx(cf_max)


def test_schedule_dependency_graph_is_dag():
    blocks = _blocks(3)
    g = sc.dependency_graph(sc.forward_ops(blocks, 1e12) +
                            sc.backward_ops(blocks, 1e12))
    assert g.number_of_nodes() == 3 * 16


def test_reversible_peak_memory_constant_in_depth():
    """The paper's memory claim at the scheduler level: peak live set is
    O(1) in depth for the reversible pattern."""
    R = 1e12
    p4 = sc.simulate_training_iteration(_blocks(4), R)[0].peak_live_bits
    p16 = sc.simulate_training_iteration(_blocks(16), R)[0].peak_live_bits
    assert p16 <= p4 * 1.05


def test_lifetime_scales_inverse_with_throughput():
    blocks = _blocks()
    assert lt.max_data_lifetime(blocks, 2e12) == pytest.approx(
        lt.max_data_lifetime(blocks, 1e12) / 2)


def test_array_utilization_sublinear():
    """Table III: growing the array shrinks lifetime sub-linearly."""
    blocks = _blocks()
    specs = [s for b in blocks for s in (b.f1, b.f2, b.g)]
    r6 = lt.array_throughput(6, 500e6, specs)
    r12 = lt.array_throughput(12, 500e6, specs)
    assert r6 < r12 < 4 * r6          # 4× cells, < 4× effective throughput
    l6 = lt.max_data_lifetime(blocks, r6)
    l12 = lt.max_data_lifetime(blocks, r12)
    assert l12 < l6                    # bigger array ⇒ shorter lifetime


def test_camel_iteration_refresh_free_at_paper_scale():
    """Fig 23a: paper-scale Branch-6 blocks stay under 3.4 µs @ 100 °C."""
    arm = sim.Arm(name="camel", system=hw.SystemConfig(temp_c=100.0),
                  blocks=tuple(_blocks(6, batch=1, spatial=7, cb=32, ck=64)))
    rep = sim.run(arm)
    assert rep.refresh_free, rep.max_lifetime_s


def test_eta_advantage_over_sram_only():
    """Fig 24(b): DuDNN+CAMEL ≥2× lower ETA than FR+SRAM-only."""
    wl = dict(n_blocks=6, batch=48, spatial=7, c_branch=64, c_backbone=256)
    camel = sim.run(sim.get_arm("DuDNN+CAMEL").with_workload(**wl))
    sram = sim.run(sim.get_arm("FR+SRAM").with_workload(**wl))
    assert sram.eta_j / camel.eta_j >= 2.0, (sram.eta_j, camel.eta_j)
    assert sram.tta_s / camel.tta_s > 1.0


def test_irreversible_spills_offchip():
    wl = dict(n_blocks=6, batch=48, spatial=7, c_branch=64, c_backbone=256)
    rep = sim.run(sim.get_arm("FR+SRAM").with_workload(**wl))
    assert rep.offchip_bits > 0
    rev = sim.run(sim.get_arm("DuDNN+CAMEL").with_workload(**wl))
    assert rev.offchip_bits == 0
