"""ReversibleStack: inversion, gradient correctness, and the O(1)-residual
memory claim (CAMEL's central mechanism) verified on compiled artifacts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core.reversible import ReversibleStack, stack_params
from repro.models import layers as L

P32 = L.Policy(compute_dtype=jnp.float32)
D = 16


def _f_apply(p, x):
    return jnp.tanh(L.dense(p, x, policy=P32))


def _init_block(key):
    k1, k2 = jax.random.split(key)
    return {"f1": L.dense_init(k1, D, D), "f2": L.dense_init(k2, D, D)}


def _plain_forward(params, x1, x2, inj):
    """Autodiff reference: identical math, no custom_vjp."""
    def body(carry, xs):
        x1, x2 = carry
        p, z = xs
        x2 = x2 + z
        y2 = x2 + _f_apply(p["f1"], x1)
        y1 = x1 + _f_apply(p["f2"], y2)
        return (y1, y2), None
    (y1, y2), _ = lax.scan(body, (x1, x2), (params, inj))
    return y1, y2


@pytest.fixture(scope="module")
def setup():
    n_blocks = 4
    params = stack_params(_init_block, jax.random.PRNGKey(0), n_blocks)
    x1 = jax.random.normal(jax.random.PRNGKey(1), (2, 8, D))
    x2 = jax.random.normal(jax.random.PRNGKey(2), (2, 8, D))
    inj = jax.random.normal(jax.random.PRNGKey(3), (n_blocks, 2, 8, D)) * 0.1
    stack = ReversibleStack(_f_apply, _f_apply)
    return stack, params, x1, x2, inj


def test_forward_matches_plain(setup):
    stack, params, x1, x2, inj = setup
    y1, y2 = stack(params, x1, x2, inj)
    r1, r2 = _plain_forward(params, x1, x2, inj)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(r1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(r2), rtol=1e-6)


def test_inversion_recovers_inputs(setup):
    """eq 2: inputs recomputed from outputs to float precision."""
    stack, params, x1, x2, inj = setup
    y1, y2 = stack.forward_only(params, x1, x2, inj)
    r1, r2 = stack.invert(params, y1, y2, inj)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(x1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r2), np.asarray(x2), atol=1e-5)


def test_gradients_match_autodiff(setup):
    stack, params, x1, x2, inj = setup

    def loss_rev(p, a, b, z):
        y1, y2 = stack(p, a, b, z)
        return jnp.sum(y1 * 1.3 + y2 ** 2)

    def loss_plain(p, a, b, z):
        y1, y2 = _plain_forward(p, a, b, z)
        return jnp.sum(y1 * 1.3 + y2 ** 2)

    g_rev = jax.grad(loss_rev, argnums=(0, 1, 2, 3))(params, x1, x2, inj)
    g_ref = jax.grad(loss_plain, argnums=(0, 1, 2, 3))(params, x1, x2, inj)
    for a, b in zip(jax.tree_util.tree_leaves(g_rev),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_no_inj_defaults_to_zero(setup):
    stack, params, x1, x2, _ = setup
    n = 4
    y = stack(params, x1, x2)
    z = stack(params, x1, x2, jnp.zeros((n, 2, 8, D)))
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(z[0]), rtol=1e-6)


def test_compiled_memory_o1_vs_oL():
    """The paper's memory claim on the compiled artifact: growing the block
    count grows the *plain* backward residuals ~linearly but leaves the
    reversible residuals ~flat."""
    def temp_bytes(n_blocks, rev: bool):
        params = stack_params(_init_block, jax.random.PRNGKey(0), n_blocks)
        x = jnp.zeros((8, 128, D))
        inj = jnp.zeros((n_blocks, 8, 128, D))
        stack = ReversibleStack(_f_apply, _f_apply)
        fwd = stack if rev else _plain_forward

        def loss(p, a, b, z):
            y1, y2 = fwd(p, a, b, z) if rev else _plain_forward(p, a, b, z)
            return jnp.sum(y1) + jnp.sum(y2)

        c = jax.jit(jax.grad(loss)).lower(params, x, x, inj).compile()
        ma = c.memory_analysis()
        return ma.temp_size_in_bytes

    rev_growth = temp_bytes(16, True) - temp_bytes(4, True)
    plain_growth = temp_bytes(16, False) - temp_bytes(4, False)
    # plain autodiff stores 12 extra block activations; reversible stores none
    assert plain_growth > 4 * max(rev_growth, 1), (
        f"plain {plain_growth} vs rev {rev_growth}")
