"""Train-step integration: duplex loss decreases, backbone stays frozen,
full baseline trains, microbatching is consistent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import duplex as dx
from repro.models import layers as L, registry
from repro.optim import AdamWConfig, SGDConfig
from repro.train import train_step as ts

P32 = L.Policy(compute_dtype=jnp.float32)
DCFG = dx.DuplexConfig(n_blocks=2, d_branch=16, pool_factor=4, branch_heads=2,
                       bfp=L.BFPPolicy(enabled=False))


def _batch(cfg, b=4, s=16, key=0):
    tokens = jax.random.randint(jax.random.PRNGKey(key), (b, s), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)
    return {"tokens": tokens, "labels": labels}


def test_duplex_step_trains_and_freezes_backbone():
    entry = registry.get("qwen2-72b")
    cfg = entry.smoke
    tcfg = ts.TrainConfig(mode="duplex", duplex=DCFG,
                          opt=AdamWConfig(weight_decay=0.0), lr=3e-3,
                          backbone_dtype=jnp.float32)
    state = ts.init_state(jax.random.PRNGKey(0), entry, cfg, tcfg, P32)
    step = jax.jit(ts.make_train_step(entry, cfg, tcfg, P32))

    batch = _batch(cfg)
    bb_before = jax.tree_util.tree_leaves(state["backbone"])
    losses = []
    for i in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    bb_after = jax.tree_util.tree_leaves(state["backbone"])
    for a, b in zip(bb_before, bb_after):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert losses[-1] < losses[0], losses     # memorizes a fixed batch
    assert int(state["step"]) == 8


def test_full_step_trains_backbone():
    entry = registry.get("granite-moe-1b-a400m")   # exercises MoE aux loss
    cfg = entry.smoke
    tcfg = ts.TrainConfig(mode="full", opt=AdamWConfig(weight_decay=0.0),
                          lr=3e-3)
    state = ts.init_state(jax.random.PRNGKey(1), entry, cfg, tcfg, P32)
    step = jax.jit(ts.make_train_step(entry, cfg, tcfg, P32))
    batch = _batch(cfg)
    losses = []
    for _ in range(6):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


def test_microbatch_equals_fullbatch_gradients():
    entry = registry.get("granite-3-8b")
    cfg = entry.smoke
    base = dict(mode="duplex", duplex=DCFG, lr=1e-2,
                opt=SGDConfig(momentum=0.0, weight_decay=0.0, clip_norm=None),
                backbone_dtype=jnp.float32)
    t1 = ts.TrainConfig(**base, microbatch=1)
    t4 = ts.TrainConfig(**base, microbatch=4)
    s0 = ts.init_state(jax.random.PRNGKey(2), entry, cfg, t1, P32)
    batch = _batch(cfg, b=8)

    s1, _ = jax.jit(ts.make_train_step(entry, cfg, t1, P32))(s0, batch)
    s4, _ = jax.jit(ts.make_train_step(entry, cfg, t4, P32))(s0, batch)
    for a, b in zip(jax.tree_util.tree_leaves(s1["branch"]),
                    jax.tree_util.tree_leaves(s4["branch"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_duplex_on_ssm_backbone():
    """Technique applies to attention-free backbones too (DESIGN §4)."""
    entry = registry.get("mamba2-780m")
    cfg = entry.smoke
    tcfg = ts.TrainConfig(mode="duplex", duplex=DCFG, lr=3e-3,
                          opt=AdamWConfig(weight_decay=0.0),
                          backbone_dtype=jnp.float32)
    state = ts.init_state(jax.random.PRNGKey(3), entry, cfg, tcfg, P32)
    step = jax.jit(ts.make_train_step(entry, cfg, tcfg, P32))
    batch = _batch(cfg)
    losses = []
    for _ in range(6):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_duplex_on_encdec_backbone():
    entry = registry.get("whisper-base")
    cfg = entry.smoke
    tcfg = ts.TrainConfig(mode="duplex", duplex=DCFG, lr=3e-3,
                          opt=AdamWConfig(weight_decay=0.0),
                          backbone_dtype=jnp.float32)
    state = ts.init_state(jax.random.PRNGKey(4), entry, cfg, tcfg, P32)
    step = jax.jit(ts.make_train_step(entry, cfg, tcfg, P32))
    batch = _batch(cfg)
    batch["frontend"] = {"frames": jax.random.normal(
        jax.random.PRNGKey(5),
        (4, cfg.n_frontend_tokens, cfg.frontend_dim)) * 0.1}
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
