"""The flight-recorder stack (``repro.obs``): span-vs-report
reconciliation, Chrome-trace export, the structured logger, and the
stage profiler.

The two contracts this file pins:

- **Observation-only** — with tracing/profiling on, every ``ArmReport``
  number is bit-identical to the untraced run (the recorder never feeds
  back into timing or energy).
- **Exact reconciliation** — ``reconcile`` re-derives the report's
  stall/refresh scalars from the recorded spans with ``==`` equality
  (the derivation replicates the engine's float summation grouping),
  across every registry arm × granularity × temperature, and survives
  the Chrome-trace JSON round-trip.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro import obs, sim
from repro.obs import log
from repro.obs.export import recorder_from_trace, trace_dict
from repro.obs.recorder import SpanRecorder

ARMS = ("DuDNN+CAMEL", "FR+SRAM", "CA+CAMEL", "BO+CAMEL")
TOOLS = Path(__file__).resolve().parent.parent / "tools"


def _arm(name, gran, temp):
    return sim.get_arm(name).with_system(temp_c=temp,
                                         refresh_granularity=gran)


# ------------------------------------------------- exact reconciliation

@pytest.mark.parametrize("name", ARMS)
@pytest.mark.parametrize("gran", ("bank", "row"))
@pytest.mark.parametrize("temp", (60.0, 100.0))
def test_reconcile_exact_across_grid(name, gran, temp):
    rep = sim.run(_arm(name, gran, temp), trace=True)
    res = obs.reconcile(rep.trace, rep)
    assert res.ok, str(res)
    # exact means ==, not approx: spot-check the derived dict too
    derived = obs.derive(rep.trace)
    assert derived["stall_s"] == rep.stall_s
    assert derived["refresh_stall_s"] == rep.refresh_stall_s
    assert derived["refresh_hidden_j"] == rep.refresh_hidden_j
    assert derived["rows_refreshed"] == rep.rows_refreshed


def test_reconcile_detects_tampering():
    rep = sim.run(_arm("DuDNN+CAMEL", "bank", 100.0), trace=True)
    rec = rep.trace
    # drop a refresh span: the hidden-energy split must stop matching
    victim = next(i for i, s in enumerate(rec.spans)
                  if s.kind in ("refresh", "refresh_stall"))
    rec.spans.pop(victim)
    assert not obs.reconcile(rec, rep).ok


def test_reconcile_requires_timeline_trace():
    rep = sim.run(sim.get_arm("DuDNN+CAMEL"), trace=True,
                  timing="additive")
    assert rep.trace.meta["timing"] == "additive"
    with pytest.raises(ValueError, match="timeline"):
        obs.reconcile(rep.trace, rep)


def test_reconcile_roundtrips_through_chrome_trace(tmp_path):
    rep = sim.run(_arm("DuDNN+CAMEL", "row", 100.0), trace=True)
    path = tmp_path / "t.trace.json"
    obs.export_chrome_trace(rep.trace, path, report=rep)
    rec, report_dict = recorder_from_trace(json.loads(path.read_text()))
    assert report_dict is not None
    res = obs.reconcile(rec, report_dict)
    assert res.ok, str(res)


# ---------------------------------------------------- observation-only

@pytest.mark.parametrize("name", ("DuDNN+CAMEL", "FR+SRAM"))
def test_trace_and_profile_leave_report_bit_identical(name):
    arm = _arm(name, "bank", 100.0)
    plain = sim.run(arm)
    traced = sim.run(arm, trace=True)
    prof = sim.run(arm, profile=True)
    assert plain.to_dict() == traced.to_dict()
    d = prof.to_dict()
    assert set(d) - set(plain.to_dict()) == {"profile"}
    d.pop("profile")
    assert plain.to_dict() == d
    # dataclass equality ignores the compare=False observability fields
    assert plain == traced == prof


def test_profile_records_every_stage():
    rep = sim.run(sim.get_arm("DuDNN+CAMEL"), profile=True)
    stages = rep.profile["stages"]
    assert tuple(stages) == sim.DEFAULT_PIPELINE.stage_names()
    assert all(w >= 0.0 for w in stages.values())
    assert rep.profile["total_s"] == sum(stages.values())
    # profile survives the JSON round-trip; untraced reports omit the key
    back = sim.ArmReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert back.profile == rep.profile
    assert "profile" not in sim.run(sim.get_arm("DuDNN+CAMEL")).to_dict()


def test_aggregate_profiles():
    reps = sim.sweep([sim.get_arm("DuDNN+CAMEL")],
                     temps=[60.0, 100.0], profile=True)
    agg = obs.aggregate_profiles(reps)
    assert set(agg) == set(sim.DEFAULT_PIPELINE.stage_names())
    mem = agg["memory"]
    assert mem["total_s"] >= mem["max_s"] >= mem["mean_s"] > 0.0
    # reports without profiles aggregate to nothing
    assert obs.aggregate_profiles([sim.run(sim.get_arm("FR+SRAM"))]) == {}


# -------------------------------------------------------- trace export

def _chrome_events(rep):
    return trace_dict(rep.trace, report=rep)["traceEvents"]


def test_export_schema_and_sorted_ts():
    rep = sim.run(_arm("DuDNN+CAMEL", "bank", 100.0), trace=True)
    events = _chrome_events(rep)
    body = [e for e in events if e["ph"] != "M"]
    assert body and all(e["ph"] in ("X", "C", "i") for e in body)
    ts = [e["ts"] for e in body]
    assert ts == sorted(ts)
    for e in body:
        assert isinstance(e["pid"], int) and e["name"]
        if e["ph"] == "X":
            assert e["dur"] >= 0
            assert e["args"]["t0_s"] <= e["args"]["t1_s"]
    # one pid per bank + the array pid, each named via metadata
    names = {(m["pid"], m["args"]["name"]) for m in events
             if m["ph"] == "M" and m["name"] == "process_name"}
    assert (0, "array") in names
    assert any(n.startswith("bank ") for _, n in names)


def test_engine_span_tracks_never_overlap():
    """Op, port, and hidden-refresh tracks are non-overlapping by
    construction of the timeline engine — per (bank, kind)."""
    for gran in ("bank", "row"):
        rep = sim.run(_arm("DuDNN+CAMEL", gran, 100.0), trace=True)
        tracks: dict = {}
        for s in rep.trace.spans:
            if s.kind in ("op", "port", "refresh"):
                tracks.setdefault((s.bank, s.kind), []).append(s)
        assert tracks
        for spans in tracks.values():
            spans = sorted(spans, key=lambda s: (s.t0, s.t1))
            for a, b in zip(spans, spans[1:]):
                assert b.t0 >= a.t1, (gran, a, b)


def test_check_trace_tool_passes_and_fails(tmp_path):
    rep = sim.run(_arm("DuDNN+CAMEL", "bank", 100.0), trace=True)
    good = tmp_path / "good.trace.json"
    obs.export_chrome_trace(rep.trace, good, report=rep)
    proc = subprocess.run(
        [sys.executable, str(TOOLS / "check_trace.py"), str(good)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr

    # corrupt the embedded report: the tool must catch the mismatch
    trace = json.loads(good.read_text())
    trace["otherData"]["report"]["refresh_stall_s"] += 1.0
    bad = tmp_path / "bad.trace.json"
    bad.write_text(json.dumps(trace))
    proc = subprocess.run(
        [sys.executable, str(TOOLS / "check_trace.py"), str(bad)],
        capture_output=True, text=True)
    assert proc.returncode == 1
    assert "reconcile" in proc.stdout


def test_recorder_rejects_unknown_kind():
    rec = SpanRecorder()
    with pytest.raises(ValueError, match="unknown span kind"):
        rec.span("nonsense", "x", 0.0, 1.0)


# ----------------------------------------------------- structured logs

def test_log_threshold_env(capsys, monkeypatch):
    monkeypatch.delenv(log.ENV_VAR, raising=False)
    assert not log.info("hidden_event", a=1)       # default level: warn
    assert log.warn("shown_event", x=1.5, s="two words")
    err = capsys.readouterr().err
    assert "hidden_event" not in err
    assert '[repro:warn] shown_event x=1.5 s="two words"' in err

    monkeypatch.setenv(log.ENV_VAR, "debug")
    assert log.debug("now_visible")
    monkeypatch.setenv(log.ENV_VAR, "error")
    assert not log.warn("suppressed")
    assert log.log("info", "forced_anyway", force=True)
    monkeypatch.setenv(log.ENV_VAR, "bogus-level")
    assert log.threshold() == log.LEVELS[log.DEFAULT_LEVEL]


def test_sweep_progress_callback_and_log(capsys):
    seen = []
    reps = sim.sweep([sim.get_arm("DuDNN+CAMEL")], temps=[60.0, 100.0],
                     progress=lambda i, name, dt: seen.append((i, name)))
    assert len(reps) == 2
    assert sorted(seen) == [(0, "DuDNN+CAMEL"), (1, "DuDNN+CAMEL")]
    # progress=True emits forced stderr lines regardless of REPRO_LOG
    sim.sweep([sim.get_arm("FR+SRAM")], temps=[60.0], progress=True)
    err = capsys.readouterr().err
    assert "[repro:info] sweep_point" in err and "arm=FR+SRAM" in err


def test_sweep_parallel_progress_keeps_grid_order():
    plain = sim.sweep([sim.get_arm(n) for n in ARMS])
    seen = []
    par = sim.sweep([sim.get_arm(n) for n in ARMS], parallel=2,
                    progress=lambda i, name, dt: seen.append(i))
    assert [r.arm for r in par] == [r.arm for r in plain] == list(ARMS)
    assert sorted(seen) == [0, 1, 2, 3]
    assert par == plain
