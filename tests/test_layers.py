"""Layer primitives: attention variants agree with each other; norms; rope."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L

P32 = L.Policy(compute_dtype=jnp.float32)


def _qkv(key, b=2, sq=64, skv=64, nkv=2, g=2, hd=8):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(kq, (b, sq, nkv * g, hd))   # flat query heads
    k = jax.random.normal(kk, (b, skv, nkv, hd))
    v = jax.random.normal(kv, (b, skv, nkv, hd))
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("causal_skip", [False, True])
def test_blockwise_matches_full(causal, window, causal_skip):
    q, k, v = _qkv(0)
    want = L.full_attention(q, k, v, causal=causal, window=window)
    got = L.blockwise_attention(q, k, v, causal=causal, window=window,
                                q_chunk=16, kv_chunk=16,
                                causal_skip=causal_skip)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_unaligned_lengths():
    q, k, v = _qkv(1, sq=50, skv=50)
    want = L.full_attention(q, k, v, causal=True)
    got = L.blockwise_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_softcap_applied():
    q, k, v = _qkv(2)
    a = L.full_attention(q * 10, k * 10, v, causal=True, softcap=5.0)
    b = L.full_attention(q * 10, k * 10, v, causal=True, softcap=None)
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_decode_matches_incremental_full():
    """Decoding token-by-token equals full causal attention, incl. rope."""
    cfg = L.AttnConfig(d_model=32, n_heads=4, n_kv=2, head_dim=8,
                       blockwise_threshold=10_000)
    key = jax.random.PRNGKey(3)
    p = L.attn_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 6, 32))

    full = L.attention_layer(p, x, cfg, policy=P32)

    cache = L.attn_cache_init(cfg, batch=2, max_len=8, dtype=jnp.float32)
    outs = []
    for t in range(6):
        o, cache = L.attention_decode(p, x[:, t:t + 1], cache, cfg, policy=P32)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_decode_matches_layer():
    cfg = L.AttnConfig(d_model=32, n_heads=4, n_kv=4, head_dim=8, window=3,
                       blockwise_threshold=10_000)
    p = L.attn_init(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 7, 32))
    full = L.attention_layer(p, x, cfg, policy=P32)
    cache = L.attn_cache_init(cfg, batch=1, max_len=8, dtype=jnp.float32)
    outs = []
    for t in range(7):
        o, cache = L.attention_decode(p, x[:, t:t + 1], cache, cfg, policy=P32)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), rtol=2e-4, atol=2e-4)


def test_cross_attention_no_causal():
    cfg = L.AttnConfig(d_model=16, n_heads=2, n_kv=2, head_dim=8,
                       rope_theta=None)
    p = L.attn_init(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 5, 16))
    enc = jax.random.normal(jax.random.PRNGKey(9), (1, 11, 16))
    out = L.attention_layer(p, x, cfg, policy=P32, kv_x=enc)
    assert out.shape == (1, 5, 16)
    assert np.all(np.isfinite(np.asarray(out)))


def test_rope_relative_property():
    """RoPE: scores depend only on relative positions."""
    x = jax.random.normal(jax.random.PRNGKey(10), (1, 4, 2, 8))
    y = jax.random.normal(jax.random.PRNGKey(11), (1, 4, 2, 8))
    p0 = jnp.arange(4)[None, :]
    p5 = p0 + 5
    s0 = jnp.einsum("bshd,bthd->bhst", L.rope(x, p0), L.rope(y, p0))
    s5 = jnp.einsum("bshd,bthd->bhst", L.rope(x, p5), L.rope(y, p5))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s5), rtol=1e-4,
                               atol=1e-4)


def test_norms():
    d = 16
    x = jax.random.normal(jax.random.PRNGKey(12), (3, d)) * 4 + 2
    rn = L.rmsnorm(L.rmsnorm_init(d), x)
    assert np.allclose(np.asarray(jnp.mean(rn**2, -1)), 1.0, atol=1e-3)
    ln = L.layernorm(L.layernorm_init(d), x)
    assert np.allclose(np.asarray(jnp.mean(ln, -1)), 0.0, atol=1e-3)
    assert np.allclose(np.asarray(jnp.var(ln, -1)), 1.0, atol=1e-2)


def test_vocab_padding_masks_logits():
    p = L.embed_init(jax.random.PRNGKey(13), vocab=100, d=8, pad_to=16)
    assert p["table"].shape[0] == 112
    x = jax.random.normal(jax.random.PRNGKey(14), (1, 2, 8))
    logits = L.unembed_logits(p, x, vocab=100, policy=P32)
    assert logits.shape == (1, 2, 112)
    assert np.all(np.asarray(logits[..., 100:]) < -1e29)


def test_bfp_dense_matches_reference():
    from repro.core import bfp
    p = L.dense_init(jax.random.PRNGKey(15), 12, 8)
    x = jax.random.normal(jax.random.PRNGKey(16), (4, 12))
    pol = L.BFPPolicy(enabled=True, group=(3, 3))
    got = L.dense(p, x, policy=P32, bfp=pol)
    want = bfp.bfp_matmul_ref(x, p["w"], group=(3, 3))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
