"""Row-granular refresh pulses (repro.memory rows) and the cross-model
invariants of the memory–timeline–cost stack: row-granular refresh stall
never exceeds bank-granular, refresh energy is granularity-invariant to
machine precision, ``pulse_exceeds_retention`` clears once a single
row's pulse fits the retention interval, the leakage-energy term makes
the energy-optimal DVFS point interior, and the memory-bound (non-linear)
``OperatingPoint.op_seconds`` path."""
import dataclasses
import json
import math

import pytest

from repro import sim
from repro.core import edram as ed
from repro.core.schedule import OpWork, TraceEvent
from repro.memory import BankGeometry, BankState, RefreshScheduler
from repro.sim.cost import DVFSState, FixedClock, OperatingPoint, op_timer
from repro.sim.timeline import replay_timeline

WORD = ed.EDRAMConfig().word_bits


def _le(row_stall, bank_stall):
    """row ≤ bank up to float rounding: a fully-preempting tick's row
    stall is a sum of per-row divisions vs one whole-bank division."""
    return row_stall <= bank_stall * (1 + 1e-9) + 1e-18


# ------------------------------------------------------------ row geometry

def test_geometry_derives_rows_from_edram_config():
    cfg = ed.EDRAMConfig()
    geom = BankGeometry.from_edram(cfg)
    # EDRAMConfig.words_per_bank is the paper's wordline count per bank
    assert geom.rows_per_bank == cfg.words_per_bank == 1024
    assert geom.words_per_row == math.ceil(geom.words_per_bank / 1024)
    assert geom.words_per_row >= 1
    assert geom.rows_for(0) == 0
    assert geom.rows_for(1) == 1
    assert geom.rows_for(geom.words_per_bank) <= geom.rows_per_bank + 1


def test_geometry_without_rows_degenerates_to_bank():
    geom = BankGeometry(word_bits=58, words_per_bank=100, n_banks=1)
    assert geom.rows_per_bank == 0
    assert geom.words_per_row == 100      # one row spans the bank
    assert geom.rows_for(37) == 1


def test_scheduler_rejects_unknown_granularity():
    with pytest.raises(ValueError, match="unknown refresh granularity"):
        RefreshScheduler("always", temp_c=60.0, granularity="wordline")


# -------------------------------------------------- row pulse placement

def _row_bank(rows_per_bank=10, words_per_bank=100):
    return BankState(0, BankGeometry(word_bits=58,
                                     words_per_bank=words_per_bank,
                                     n_banks=1,
                                     rows_per_bank=rows_per_bank))


def test_row_pulses_pack_into_idle_gaps():
    """50 peak words over 10-word rows = 5 row pulses of 0.1 s each at
    100 Hz; a busy span [0, 2) in a 2 s interval forces tick-1 stalls
    while tick 2 hides all rows back-to-back."""
    b = _row_bank()
    b.peak_words = 50
    b.occ_bit_s = 1.0
    sched = RefreshScheduler("always", temp_c=60.0, interval_s=2.0,
                             granularity="row")
    b.occupy_port(0.0, 2.0)
    pulses = sched.place_pulses(b, duration_s=4.0, freq_hz=100.0)
    assert sum(p.rows for p in pulses) == 2 * 5      # ticks × rows
    tick1 = [p for p in pulses if p.index == 1]
    tick2 = [p for p in pulses if p.index == 2]
    # tick 1 has no idle gap: its 5 rows preempt as one aggregated run
    (run,) = tick1
    assert not run.hidden and run.rows == 5 and run.words == 50
    assert run.stall_s == pytest.approx(0.5)
    assert run.start_s == run.deadline_s == pytest.approx(2.0)
    assert all(p.hidden and p.stall_s == 0.0 and p.rows == 1
               for p in tick2)
    # hidden pulses pack back-to-back from the start of the idle gap,
    # never overlapping each other or the busy span
    starts = sorted(p.start_s for p in tick2)
    assert starts[0] == pytest.approx(2.0)
    for a, nxt in zip(starts, starts[1:]):
        assert nxt == pytest.approx(a + 0.1)
    assert all(p.start_s + 0.1 <= 4.0 + 1e-12 for p in tick2)
    assert {p.row for p in tick2} == set(range(5))


def test_partial_last_row_pulse_is_shorter():
    b = _row_bank()
    b.peak_words = 23                                # 2 full rows + 3 words
    b.occ_bit_s = 1.0
    sched = RefreshScheduler("always", temp_c=60.0, interval_s=2.0,
                             granularity="row")
    pulses = sched.place_pulses(b, duration_s=2.0, freq_hz=100.0)
    assert [p.words for p in pulses] == [10, 10, 3]
    assert sum(p.words for p in pulses) == b.peak_words


def test_row_pulses_hide_where_one_bank_pulse_cannot():
    """The tentpole case: the bank-granular pulse is wider than every
    idle gap, but the per-row pulses thread through them."""
    b = _row_bank()
    b.peak_words = 50                    # bank pulse 0.5 s; row pulse 0.1 s
    b.occ_bit_s = 1.0
    # comb of busy spans leaving 0.15 s gaps — never 0.5 s
    for k in range(8):
        b.occupy_port(k * 0.25, k * 0.25 + 0.10)
    bank_sched = RefreshScheduler("always", temp_c=60.0, interval_s=2.0)
    row_sched = RefreshScheduler("always", temp_c=60.0, interval_s=2.0,
                                 granularity="row")
    bank_pulses = bank_sched.place_pulses(b, duration_s=2.0, freq_hz=100.0)
    row_pulses = row_sched.place_pulses(b, duration_s=2.0, freq_hz=100.0)
    assert [p.hidden for p in bank_pulses] == [False]
    assert all(p.hidden for p in row_pulses)
    assert sum(p.stall_s for p in row_pulses) < sum(
        p.stall_s for p in bank_pulses)


# ------------------------------------- fig24 grid: row ≤ bank, energy ==

FIG24_ARMS = ("DuDNN+CAMEL", "FR+SRAM", "CA+CAMEL", "BO+CAMEL")
GRID_TEMPS = (60.0, 100.0)
GRID_FREQS = (None, 250e6, 62.5e6)     # default, down-clocked, crawl


def _grid(granularity):
    arms = [sim.get_arm(n).with_system(refresh_granularity=granularity)
            for n in FIG24_ARMS]
    return sim.sweep(arms, temps=GRID_TEMPS, freqs=GRID_FREQS)


def test_row_stall_never_exceeds_bank_across_fig24_grid():
    """ISSUE invariant: on every Fig-24 arm × {60,100} °C × {default,
    250 MHz, 62.5 MHz} the row-granular refresh stall is ≤ the
    bank-granular one, and refresh energy is exactly equal."""
    bank = _grid("bank")
    row = _grid("row")
    assert len(bank) == len(row) == len(FIG24_ARMS) * len(GRID_TEMPS) \
        * len(GRID_FREQS)
    refreshed_points = 0
    for b, r in zip(bank, row):
        assert r.arm == b.arm and r.freq_hz == b.freq_hz
        assert _le(r.refresh_stall_s, b.refresh_stall_s)
        # granularity moves time, never energy — exact, not approx
        assert r.memory["refresh_j"] == b.memory["refresh_j"]
        assert r.memory["read_j"] == b.memory["read_j"]
        assert r.memory["write_j"] == b.memory["write_j"]
        assert r.memory_j == b.memory_j
        assert r.refresh_free == b.refresh_free
        if b.memory["refresh_j"] > 0.0:
            refreshed_points += 1
            assert r.rows_refreshed > 0
            assert 0.0 <= r.row_hidden_frac <= 1.0
        else:
            assert r.rows_refreshed == 0
    assert refreshed_points > 0            # the grid exercises refresh


def test_bank_default_is_bit_identical_to_explicit_bank():
    arm = sim.get_arm("DuDNN+CAMEL").with_system(temp_c=100.0,
                                                 alloc_policy="lifetime")
    explicit = sim.run(arm.with_system(refresh_granularity="bank"))
    assert sim.run(arm).to_dict() == explicit.to_dict()


def test_row_granularity_strictly_cuts_stall_on_flagged_config():
    """Acceptance: the hot/full/down-clocked config that flags
    pulse_exceeds_retention under bank granularity stops flagging under
    row granularity, strictly reduces refresh_stall_s, and keeps refresh
    energy equal to machine precision."""
    base = sim.get_arm("DuDNN+CAMEL").with_system(temp_c=100.0,
                                                  alloc_policy="lifetime")
    slow = FixedClock(freq_hz=250e6)
    bank = sim.run(base.with_cost(slow))
    row = sim.run(base.with_system(refresh_granularity="row")
                  .with_cost(slow))
    assert bank.pulse_exceeds_retention          # whole-bank pulse > interval
    assert not row.pulse_exceeds_retention       # one row's pulse fits
    assert row.refresh_stall_s < bank.refresh_stall_s
    assert row.memory["refresh_j"] == bank.memory["refresh_j"]
    assert row.latency_s < bank.latency_s
    assert row.rows_refreshed > 0
    assert 0.0 < row.row_hidden_frac < 1.0
    assert row.memory["granularity"] == "row"
    assert any(b["rows_refreshed"] > 0 for b in row.memory["banks"])


def test_pulse_exceeds_retention_clears_when_row_fits():
    """The saturated-bank replay from tests/test_cost.py: the 8 µs
    whole-bank pulse exceeds the 6.7 µs interval, but one row's pulse is
    ~10 ns — row granularity must clear the flag."""
    cfg = ed.EDRAMConfig()
    words = 4000
    events = [TraceEvent(0.0, "BIG", "big", "write", WORD * words),
              TraceEvent(0.0, "BIG", "big", "read", WORD * words)]
    schedule = [("BIG", 0.0, 10e-6)]
    kw = dict(op_schedule=schedule, temp_c=60.0, duration_s=10e-6,
              refresh_policy="always", alloc_policy="first_fit",
              freq_hz=500e6)
    bank = replay_timeline(events, cfg, **kw)
    row = replay_timeline(events, cfg, granularity="row", **kw)
    assert bank.pulse_exceeds_retention
    assert not row.pulse_exceeds_retention
    assert _le(row.refresh_stall_s, bank.refresh_stall_s)
    assert row.refresh_j == bank.refresh_j
    assert row.granularity == "row" and bank.granularity == "bank"
    assert row.rows_refreshed > 0


def test_row_report_roundtrips_through_json():
    rep = sim.run(sim.get_arm("DuDNN+CAMEL").with_system(
        temp_c=100.0, alloc_policy="lifetime", refresh_granularity="row"))
    assert rep.rows_refreshed > 0
    back = sim.ArmReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert back == rep
    assert back.rows_refreshed == rep.rows_refreshed
    assert back.row_hidden_frac == rep.row_hidden_frac
    assert back.memory["granularity"] == "row"
    assert back.config["system"]["refresh_granularity"] == "row"


def test_additive_stall_total_is_granularity_invariant():
    """Under the additive model one tick's row pulses serialize to the
    same port time as the bank pulse — stall and energy both match."""
    arm = sim.get_arm("DuDNN+CAMEL").with_system(temp_c=100.0,
                                                 alloc_policy="lifetime")
    bank = sim.run(arm, timing="additive")
    row = sim.run(arm.with_system(refresh_granularity="row"),
                  timing="additive")
    assert row.refresh_stall_s == bank.refresh_stall_s
    assert row.memory["refresh_j"] == bank.memory["refresh_j"]
    assert row.rows_refreshed > 0          # rows are still counted


# ------------------------------------------------- leakage energy charge

def test_leakage_is_charged_over_wall_clock_latency():
    arm = sim.get_arm("DuDNN+CAMEL")
    base = sim.run(arm)
    leak = sim.run(arm.with_system(charge_leakage=True))
    assert base.leakage_j == 0.0
    kb = arm.system.onchip_bits / 8.0 / 1024.0
    want = arm.system.edram.leakage_mw_per_kb * 1e-3 * kb * leak.latency_s
    assert leak.leakage_j == pytest.approx(want, rel=1e-12)
    assert leak.latency_s == base.latency_s        # leakage moves energy only
    assert leak.energy_j == pytest.approx(base.energy_j + leak.leakage_j)


def test_sram_arm_leaks_at_the_sram_rate():
    arm = sim.get_arm("FR+SRAM").with_system(charge_leakage=True)
    rep = sim.run(arm)
    kb = arm.system.onchip_bits / 8.0 / 1024.0
    want = arm.system.edram.sram_leakage_mw_per_kb * 1e-3 * kb \
        * rep.latency_s
    assert rep.leakage_j == pytest.approx(want, rel=1e-12)


def test_energy_optimal_dvfs_point_is_interior_with_leakage():
    """ROADMAP follow-up: without the leakage term the slowest clock is
    always energy-optimal (dynamic compute energy ∝ V² only falls as f
    drops); charging leakage × wall-clock makes slow points pay for the
    time they stretch over, so the optimum moves to an interior point."""
    freqs = [DVFSState(freq_hz=f)
             for f in (500e6, 250e6, 125e6, 62.5e6, 31.25e6)]
    base = sim.get_arm("DuDNN+CAMEL").with_system(refresh_policy="none")
    no_leak = sim.sweep([base], freqs=freqs)
    leak = sim.sweep([base.with_system(refresh_policy="none",
                                       charge_leakage=True)], freqs=freqs)
    best_free = min(range(len(freqs)), key=lambda i: no_leak[i].energy_j)
    assert best_free == len(freqs) - 1             # slowest looks free
    best = min(range(len(freqs)), key=lambda i: leak[i].energy_j)
    assert 0 < best < len(freqs) - 1               # now interior
    assert all(r.leakage_j > 0.0 for r in leak)
    # slower point, more leakage charged
    assert leak[-1].leakage_j > leak[0].leakage_j


# --------------------------------- memory-bound (non-linear) cost model

@dataclasses.dataclass(frozen=True)
class MemoryRailPoint(OperatingPoint):
    """An operating point whose bank ports stay on a fixed memory rail:
    MAC time scales with the core clock while port time does not, so op
    time is non-linear in 1/f (flat once port words dominate)."""
    mem_freq_hz: float = 500e6

    def op_seconds(self, work, mac_rate_s: float) -> float:
        mac_s = work.macs / mac_rate_s if mac_rate_s > 0.0 else 0.0
        port_s = (work.port_words / self.mem_freq_hz
                  if self.mem_freq_hz > 0.0 else 0.0)
        return max(mac_s, port_s)


def test_op_seconds_port_branch_dominates_mac_work():
    """PR 4 follow-up: the non-linear max() path — port-word work
    dominating MAC work — decides the op time."""
    point = OperatingPoint(freq_hz=1e8)
    bound = point.op_seconds(OpWork(macs=100.0, port_words=1e6), 1e12)
    assert bound == pytest.approx(1e6 / 1e8)       # port time, not MAC time
    # drop the port work and the same op is ~free
    assert point.op_seconds(OpWork(macs=100.0), 1e12) == \
        pytest.approx(1e-10)


def test_memory_bound_op_time_is_nonlinear_in_frequency():
    """On a fixed memory rail, a memory-bound op's time is flat across
    core clocks (port-bound knee) and only turns ∝ 1/f once MAC work
    takes over — halving f does NOT halve throughput."""
    work = OpWork(macs=4e6, port_words=4e6)        # port_s = 8 ms on the rail
    mac_rate_per_hz = 4.0                          # MAC/s per core Hz

    def at(freq_hz):
        point = MemoryRailPoint(freq_hz=freq_hz)
        fn = op_timer(point, mac_rate_per_hz * freq_hz)
        from repro.core.schedule import Op
        return fn(Op("MB", work, (), ()))

    port_s = 4e6 / 500e6
    assert at(500e6) == pytest.approx(port_s)      # mac_s 2 ms < port 8 ms
    assert at(250e6) == pytest.approx(port_s)      # still port-bound: flat
    assert at(125e6) == pytest.approx(port_s)      # knee: mac_s == port_s
    assert at(62.5e6) == pytest.approx(2 * port_s)  # mac-bound at last
