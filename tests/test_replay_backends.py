"""Differential fuzz: the vectorized replay backend vs the reference
scalar walk (``repro.memory.vector`` behind ``backend="vector"``).

The vector engine's contract is *bit-identical* reports — not approx —
so every check here is exact ``==``: controller reports under both
stall models, pulse placements, and the ``repro.obs.reconcile``
exact-equality harness run against the vector report.

Random traces cover alloc/write/read/free/evict mixes, buffered
whole-iteration tensors, spill-inducing sizes (single tensors larger
than the whole array), and residency lifetimes straddling retention
ticks.  When ``hypothesis`` is installed the same differential property
runs under its shrinker as well; the concrete seeded grid below always
runs, so the suite adds no dependency on it.
"""
import dataclasses
import random

import pytest

from repro import obs, sim
import repro.serve  # noqa: F401  (registers the Serve/* arms)
from repro.core import edram as ed
from repro.core.schedule import TraceEvent
from repro.memory import REPLAY_BACKENDS, replay, replay_core, \
    resolve_backend
from repro.memory import vector as vec
from repro.obs.recorder import SpanRecorder
from repro.sim.timeline import closed_loop_walk, replay_timeline

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - container has none
    HAVE_HYPOTHESIS = False

CFG = ed.EDRAMConfig()
WORD = CFG.word_bits
BANK_BITS = CFG.bank_kb * 1024 * 8


# ------------------------------------------------------ trace generator

def _random_trace(rng, *, n_ops=32, n_tensors=14, duration_s=1e-3):
    """A random but well-formed trace + op schedule.

    Each tensor gets a birth (``alloc`` or ``write``), sorted mid-life
    reads/rewrites, and one of ``free`` / ``evict`` / survives-to-end.
    Sizes are log-spread from one word up past a whole bank, with an
    occasional array-sized giant to force spills; ~15% of ops have zero
    duration (fused elementwise, per the schedule contract).
    """
    dt = duration_s / n_ops
    schedule = []
    for k in range(n_ops):
        dur = 0.0 if rng.random() < 0.15 else dt
        schedule.append((f"op{k}", k * dt, k * dt + dur))

    events = []
    for j in range(n_tensors):
        birth = rng.randrange(n_ops)
        death = rng.randrange(birth, n_ops)
        if rng.random() < 0.10:
            bits = float(rng.randrange(int(8 * BANK_BITS),
                                       int(16 * BANK_BITS)))
        else:
            bits = float(rng.randrange(WORD, int(2 * BANK_BITS)))
        buffered = rng.random() < 0.25
        name = f"t{j}"
        kind0 = "alloc" if rng.random() < 0.2 else "write"
        touches = []
        for _ in range(rng.randrange(0, 4)):
            k = rng.randrange(birth, death + 1)
            kind = "read" if rng.random() < 0.7 else "write"
            touches.append(TraceEvent(k * dt, f"op{k}", name, kind, bits,
                                      buffered=buffered))
        touches.sort(key=lambda e: e.time)
        tensor_events = [TraceEvent(birth * dt, f"op{birth}", name, kind0,
                                    bits, buffered=buffered)] + touches
        end = rng.random()
        if end < 0.70:
            tensor_events.append(TraceEvent(death * dt, f"op{death}",
                                            name, "free", bits,
                                            buffered=buffered))
        elif end < 0.85:
            tensor_events.append(TraceEvent(death * dt, f"op{death}",
                                            name, "evict", bits,
                                            buffered=buffered))
        events.extend(tensor_events)
    # stable sort: per-tensor event order survives equal timestamps
    events.sort(key=lambda e: e.time)
    return events, schedule, duration_s


def _random_params(rng, duration_s):
    return dict(
        temp_c=rng.choice([60.0, 100.0]),
        duration_s=duration_s,
        refresh_policy=rng.choice(["always", "selective", "none"]),
        alloc_policy=rng.choice(["pingpong", "first_fit", "lifetime"]),
        freq_hz=500e6,
        sample_scale=rng.choice([1.0, 4.0]),
        # retention straddles the trace: a handful of ticks, so some
        # tensor lifetimes cross tick boundaries and some don't
        retention_s=rng.choice([duration_s / 3, duration_s / 7, None]),
        granularity=rng.choice(["bank", "row"]),
        reads_restore=rng.random() < 0.5,
    )


# ------------------------------------------------- the differential

def _check_case(events, schedule, kw):
    """Exact equality of both stall models and the pulse placements."""
    durations = {n: e - s for n, s, e in schedule}

    add_p = replay(events, CFG, op_durations=durations, **kw)
    add_v = replay(events, CFG, op_durations=durations,
                   backend="vector", **kw)
    assert add_p == add_v

    tml_p = replay_timeline(events, CFG, op_schedule=schedule, **kw)
    tml_v = replay_timeline(events, CFG, op_schedule=schedule,
                            backend="vector", **kw)
    assert tml_p == tml_v

    # pulse placements, PulsePlacement for PulsePlacement
    core_p = replay_core(events, CFG, **kw)
    makespan = max(closed_loop_walk(core_p, schedule), kw["duration_s"])
    ref = {b.index: core_p.sched.place_pulses(b, makespan, core_p.freq_hz)
           for b in core_p.alloc.banks if core_p.sched.would_refresh(b)}
    core_v = replay_core(events, CFG, backend="vector", **kw)
    mk_v = max(vec.closed_loop_walk_vector(core_v, schedule),
               kw["duration_s"])
    assert mk_v == makespan
    pulses = vec.place_all_pulses_vector(core_v, mk_v)
    assert set(pulses) == set(ref)
    for i in sorted(ref):
        assert pulses[i].to_placements() == ref[i]
    return tml_p


def _run_seed(seed):
    rng = random.Random(seed)
    events, schedule, duration_s = _random_trace(rng)
    kw = _random_params(rng, duration_s)
    _check_case(events, schedule, kw)


@pytest.mark.parametrize("seed", range(12))
def test_fuzz_backends_bit_identical(seed):
    _run_seed(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=0, max_value=10**9))
    def test_fuzz_backends_bit_identical_hypothesis(seed):
        _run_seed(seed)


# ------------------------------------- reconciliation against the trace

@pytest.mark.parametrize("gran", ("bank", "row"))
def test_vector_report_reconciles_with_recorded_trace(gran):
    """The acid test: record the reference walk's full span history,
    then reconcile it against the *vector* report — exact equality on
    every RECONCILED_FIELDS scalar only holds if the two backends agree
    bit-for-bit on stalls, hiding splits, and row multiplicities."""
    rng = random.Random(97 if gran == "bank" else 101)
    events, schedule, duration_s = _random_trace(rng)
    kw = dict(temp_c=100.0, duration_s=duration_s,
              refresh_policy="always", alloc_policy="pingpong",
              freq_hz=500e6, retention_s=duration_s / 5,
              granularity=gran)
    rec = SpanRecorder()
    tml_p = replay_timeline(events, CFG, op_schedule=schedule,
                            recorder=rec, **kw)
    tml_v = replay_timeline(events, CFG, op_schedule=schedule,
                            backend="vector", **kw)
    assert tml_p == tml_v
    assert tml_p.refresh_count > 0         # the case exercises refresh
    res = obs.reconcile(rec, tml_v)
    assert res.ok, str(res)


# ------------------------------------------------ backend seam contract

def test_resolve_backend_validates_and_downgrades():
    assert REPLAY_BACKENDS == ("python", "vector")
    assert resolve_backend("python") == "python"
    assert resolve_backend("vector") == "vector"
    with pytest.raises(ValueError, match="unknown replay backend"):
        resolve_backend("numba")
    # a recorder forces the reference walk (span recording observes the
    # scalar walk's per-event side effects)
    assert resolve_backend("vector", recorder=object()) == "python"


def test_recorder_downgrade_is_report_invariant(capsys):
    rng = random.Random(7)
    events, schedule, duration_s = _random_trace(rng)
    kw = dict(temp_c=100.0, duration_s=duration_s,
              refresh_policy="always", alloc_policy="pingpong",
              freq_hz=500e6, retention_s=duration_s / 4,
              granularity="row")
    rec = SpanRecorder()
    downgraded = replay_timeline(events, CFG, op_schedule=schedule,
                                 backend="vector", recorder=rec, **kw)
    assert "replay_backend_downgrade" in capsys.readouterr().err
    reference = replay_timeline(events, CFG, op_schedule=schedule, **kw)
    assert downgraded == reference
    assert rec.spans                       # the trace was still recorded


# ----------------------------------------------- golden-pin arm grid

ARMS = ("DuDNN+CAMEL", "FR+SRAM", "CA+CAMEL", "BO+CAMEL", "Serve/skip")


def _comparable(report):
    """ArmReport as a dict minus the fields that legitimately differ
    across backends: ``config`` records ``replay_backend`` itself."""
    d = dataclasses.asdict(report)
    d.pop("config", None)
    d.pop("profile", None)
    d.pop("trace", None)
    return d


@pytest.mark.parametrize("name", ARMS)
@pytest.mark.parametrize("gran", ("bank", "row"))
@pytest.mark.parametrize("temp", (60.0, 100.0))
def test_vector_backend_matches_arm_goldens(name, gran, temp):
    """The Fig-24 training arms and the serving arm, both granularities
    and temperatures: the vector backend reproduces the golden-pinned
    reports (test_sim / test_serve pin the python-path numbers; this
    pins vector == python, so the goldens transfer bit-for-bit)."""
    arm = sim.get_arm(name).with_system(temp_c=temp,
                                        refresh_granularity=gran)
    ref = sim.run(arm.with_system(replay_backend="python"))
    vec_rep = sim.run(arm.with_system(replay_backend="vector"))
    assert _comparable(ref) == _comparable(vec_rep)
