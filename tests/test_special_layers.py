"""MoE / SSD / RG-LRU layers vs naive oracles; prefill↔decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import hybrid, layers as L, moe, ssm

P32 = L.Policy(compute_dtype=jnp.float32)


# ----------------------------- SSD / mamba2 --------------------------------

def _ssd_inputs(key=0, b=2, s=32, h=4, p=8, g=2, n=16):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    return x, dt, A, B, C


@pytest.mark.parametrize("chunk", [4, 8, 32, 64])
def test_ssd_chunked_matches_recurrent_oracle(chunk):
    x, dt, A, B, C = _ssd_inputs()
    want, hf_want = ssm.ssd_reference(x, dt, A, B, C)
    got, hf_got = ssm._ssd_chunked(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf_got), np.asarray(hf_want),
                               rtol=1e-4, atol=1e-4)


def test_ssd_prefill_then_decode_consistent():
    """Running [0:24] chunked then 8 single-step decodes == full prefill."""
    x, dt, A, B, C = _ssd_inputs(s=32)
    full, hf = ssm._ssd_chunked(x, dt, A, B, C, chunk=8)
    y_pre, h = ssm._ssd_chunked(x[:, :24], dt[:, :24], A, B[:, :24],
                                C[:, :24], chunk=8)
    outs = [y_pre]
    for t in range(24, 32):
        y_t, h = ssm._ssd_chunked(x[:, t:t + 1], dt[:, t:t + 1], A,
                                  B[:, t:t + 1], C[:, t:t + 1], chunk=8, h0=h)
        outs.append(y_t)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hf),
                               rtol=1e-4, atol=1e-4)


def test_ssd_block_end_to_end():
    cfg = ssm.SSDConfig(d_model=32, d_state=16, headdim=8, expand=2, chunk=8)
    params = ssm.ssd_init(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 32))
    y, _ = ssm.ssd_block(params, x, cfg, policy=P32)
    assert y.shape == x.shape and np.all(np.isfinite(np.asarray(y)))
    # stateful decode matches stateless prefill
    st = ssm.ssd_state_init(cfg, batch=2)
    outs = []
    for t in range(16):
        o, st = ssm.ssd_block(params, x[:, t:t + 1], cfg, policy=P32, state=st)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(y),
                               rtol=2e-3, atol=2e-3)


def test_ssd_gradients_finite():
    cfg = ssm.SSDConfig(d_model=16, d_state=8, headdim=8, expand=2, chunk=4)
    params = ssm.ssd_init(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 12, 16))
    g = jax.grad(lambda p: jnp.sum(ssm.ssd_block(p, x, cfg, policy=P32)[0] ** 2)
                 )(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf)))


# ----------------------------- RG-LRU --------------------------------------

def test_rg_lru_scan_matches_recurrence():
    cfg = hybrid.LRUConfig(d_model=16, lru_width=24)
    params = hybrid.lru_init(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 20, 24))
    got, hf_got = hybrid._rg_lru(params, x, P32)
    want, hf_want = hybrid.rg_lru_reference(params, x, P32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf_got), np.asarray(hf_want),
                               rtol=1e-5, atol=1e-5)


def test_lru_block_prefill_decode_consistent():
    cfg = hybrid.LRUConfig(d_model=16, lru_width=16)
    params = hybrid.lru_init(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 10, 16))
    full, _ = hybrid.lru_block(params, x, cfg, policy=P32)
    st = hybrid.lru_state_init(cfg, batch=2)
    outs = []
    for t in range(10):
        o, st = hybrid.lru_block(params, x[:, t:t + 1], cfg, policy=P32,
                                 state=st)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_rg_lru_chunked_scan_matches_full():
    """§Perf H2: chunked scan (O(chunk) temporaries) is numerically the
    same recurrence, including carried state and ragged tails."""
    cfg = hybrid.LRUConfig(d_model=16, lru_width=24)
    params = hybrid.lru_init(jax.random.PRNGKey(20), cfg)
    x = jax.random.normal(jax.random.PRNGKey(21), (2, 37, 24))
    h0 = jax.random.normal(jax.random.PRNGKey(22), (2, 24)) * 0.1
    full, hf_full = hybrid._rg_lru(params, x, P32, h0=h0)
    for chunk in (4, 8, 16, 64):
        got, hf = hybrid._rg_lru(params, x, P32, h0=h0, scan_chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(hf), np.asarray(hf_full),
                                   rtol=1e-5, atol=1e-5)


def test_lru_state_bounded():
    """|a|<1 keeps the state bounded over long rollouts (retention analogue)."""
    cfg = hybrid.LRUConfig(d_model=8, lru_width=8)
    params = hybrid.lru_init(jax.random.PRNGKey(9), cfg)
    x = jnp.ones((1, 500, 8))
    y, hf = hybrid._rg_lru(params, x, P32)
    assert float(jnp.max(jnp.abs(hf))) < 100.0


# ----------------------------- MoE ------------------------------------------

def _moe_setup(key=0, e=4, k=2, b=2, s=16, d=8, f=16, cf=2.0):
    cfg = moe.MoEConfig(d_model=d, d_ff=f, n_experts=e, top_k=k,
                        capacity_factor=cf, group_size=16)
    params = moe.moe_init(jax.random.PRNGKey(key), cfg)
    x = jax.random.normal(jax.random.PRNGKey(key + 1), (b, s, d))
    return cfg, params, x


def test_moe_shapes_and_aux():
    cfg, params, x = _moe_setup()
    y, aux = moe.moe_apply(params, x, cfg, policy=P32)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # aux loss lower bound is 1 at balance


def test_moe_matches_dense_reference_with_ample_capacity():
    """With capacity ≥ tokens, MoE == Σ_k gate_k · expert_k(x) exactly."""
    cfg, params, x = _moe_setup(cf=100.0)  # nothing dropped
    y, _ = moe.moe_apply(params, x, cfg, policy=P32)

    logits = x @ params["router"]["w"]
    gates = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(gates, cfg.top_k)
    topv = topv / topv.sum(-1, keepdims=True)

    def expert(e_idx, v):
        h = jax.nn.silu(v @ params["wg"][e_idx]) * (v @ params["wi"][e_idx])
        return h @ params["wo"][e_idx]

    want = jnp.zeros_like(x)
    for kk in range(cfg.top_k):
        idx = topi[..., kk]
        out = jax.vmap(jax.vmap(expert))(idx, x)
        want = want + topv[..., kk:kk + 1] * out
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_tokens_gracefully():
    cfg, params, x = _moe_setup(cf=0.25)  # aggressive dropping
    y, _ = moe.moe_apply(params, x, cfg, policy=P32)
    assert np.all(np.isfinite(np.asarray(y)))


def test_moe_top1_shared_expert():
    cfg = moe.MoEConfig(d_model=8, d_ff=16, n_experts=4, top_k=1,
                        group_size=16, shared_expert=True)
    params = moe.moe_init(jax.random.PRNGKey(10), cfg)
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 8, 8))
    y, _ = moe.moe_apply(params, x, cfg, policy=P32)
    assert y.shape == x.shape and np.all(np.isfinite(np.asarray(y)))


def test_moe_gradients_flow_to_router_and_experts():
    cfg, params, x = _moe_setup()
    g = jax.grad(lambda p: jnp.sum(moe.moe_apply(p, x, cfg, policy=P32)[0] ** 2)
                 )(params)
    assert float(jnp.max(jnp.abs(g["router"]["w"]))) > 0
    assert float(jnp.max(jnp.abs(g["wi"]))) > 0
