"""Pallas flash attention vs the pure-jnp full-attention oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models import layers as L


def _ref(q, k, v, causal, softcap=None):
    """Oracle: layers.full_attention on [B,S,H,d] layout."""
    out = L.full_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), causal=causal,
                           softcap=softcap)
    return out.transpose(0, 2, 1, 3)


def _inputs(key, b, h, kv, sq, skv, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, skv, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, skv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,h,kv,sq,skv,d", [
    (1, 2, 2, 64, 64, 16),     # MHA, single block pair
    (2, 4, 2, 128, 128, 32),   # GQA 2:1, multi-block
    (1, 8, 2, 64, 128, 16),    # GQA 4:1, rectangular
    (1, 3, 1, 96, 96, 8),      # MQA, 3 heads
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(b, h, kv, sq, skv, d, causal):
    q, k, v = _inputs(0, b, h, kv, sq, skv, d)
    got = flash_attention(q, k, v, causal=causal, q_chunk=32, kv_chunk=32,
                          interpret=True)
    want = _ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_softcap():
    q, k, v = _inputs(1, 1, 2, 2, 64, 64, 16)
    got = flash_attention(q * 3, k * 3, v, causal=True, softcap=20.0,
                          q_chunk=32, kv_chunk=32, interpret=True)
    want = _ref(q * 3, k * 3, v, True, softcap=20.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16_io():
    q, k, v = _inputs(2, 1, 2, 2, 64, 64, 16, dtype=jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32,
                          interpret=True)
    assert got.dtype == jnp.bfloat16
    want = _ref(q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), True)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want), rtol=2e-2, atol=2e-2)


def test_attention_layer_flash_path_matches_blockwise():
    """The runtime integration: AttnConfig(use_flash=True) end-to-end."""
    base = dict(d_model=32, n_heads=4, n_kv=2, head_dim=8,
                blockwise_threshold=8)
    cfg_ref = L.AttnConfig(**base)
    cfg_flash = L.AttnConfig(**base, use_flash=True, flash_interpret=True,
                             q_chunk=16, kv_chunk=16)
    p = L.attn_init(jax.random.PRNGKey(5), cfg_ref)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 64, 32))
    pol = L.Policy(compute_dtype=jnp.float32)
    ref = L.attention_layer(p, x, cfg_ref, policy=pol)
    got = L.attention_layer(p, x, cfg_flash, policy=pol)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_chunk_sweep():
    q, k, v = _inputs(3, 1, 2, 1, 128, 128, 16)
    want = _ref(q, k, v, True)
    for qc, kc in ((16, 32), (32, 16), (64, 64), (128, 128)):
        got = flash_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=kc,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"chunks {(qc, kc)}")
