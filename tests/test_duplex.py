"""Duplex (DuDNN) branch: causality, gradient flow, frozen backbone."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import duplex as dx
from repro.models import layers as L

P32 = L.Policy(compute_dtype=jnp.float32)
CFG = dx.DuplexConfig(n_blocks=2, d_branch=16, pool_factor=4, branch_heads=2,
                      bfp=L.BFPPolicy(enabled=False))
D_MODEL = 24


def _setup(key=0, b=2, s=16):
    params = dx.duplex_init(jax.random.PRNGKey(key), CFG, D_MODEL)
    emb = jax.random.normal(jax.random.PRNGKey(key + 1), (b, s, D_MODEL))
    taps = jax.random.normal(jax.random.PRNGKey(key + 2),
                             (CFG.n_blocks, b, s, D_MODEL))
    return params, emb, taps


def test_shapes_and_finite():
    params, emb, taps = _setup()
    out = dx.duplex_apply(params, CFG, emb, taps, policy=P32)
    assert out.shape == emb.shape
    assert np.all(np.isfinite(np.asarray(out)))


def test_pool_seq_ragged_tail():
    x = jnp.arange(10, dtype=jnp.float32).reshape(1, 10, 1)
    p = dx.pool_seq(x, 4)
    assert p.shape == (1, 3, 1)
    np.testing.assert_allclose(np.asarray(p[0, :, 0]), [1.5, 5.5, 8.5])


def test_causal_upsample_no_future_leak():
    """Correction at token t must not depend on tokens >= floor(t/r)*r."""
    params, emb, taps = _setup(s=16)

    def corr_at(emb_in, t):
        out = dx.duplex_apply(params, CFG, emb_in, taps, policy=P32)
        return out[:, t]

    # perturb the LAST token; corrections for tokens in earlier segments
    # and the current segment must be unchanged (segment = 4 tokens)
    emb2 = emb.at[:, -1].add(100.0)
    for t in range(0, 16):  # all tokens: last segment starts at 12
        a = corr_at(emb, t)
        b = corr_at(emb2, t)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   err_msg=f"leak at token {t}")


def test_first_segment_correction_is_zero():
    params, emb, taps = _setup()
    out = dx.duplex_apply(params, CFG, emb, taps, policy=P32)
    np.testing.assert_allclose(np.asarray(out[:, :CFG.pool_factor]), 0.0)


def test_backbone_receives_no_gradient():
    params, emb, taps = _setup()

    def loss(p, e, t):
        return jnp.sum(dx.duplex_apply(p, CFG, e, t, policy=P32) ** 2)

    ge, gt = jax.grad(loss, argnums=(1, 2))(params, emb, taps)
    np.testing.assert_allclose(np.asarray(ge), 0.0)
    np.testing.assert_allclose(np.asarray(gt), 0.0)


def test_branch_params_all_receive_gradient():
    params, emb, taps = _setup()

    def loss(p):
        out = dx.duplex_apply(p, CFG, emb, taps, policy=P32)
        return jnp.sum(out[:, CFG.pool_factor:] ** 2)

    g = jax.grad(loss)(params)
    flat, _ = jax.tree_util.tree_flatten_with_path(g)
    for path, leaf in flat:
        assert float(jnp.max(jnp.abs(leaf))) > 0, f"dead gradient at {path}"


def test_norm_ablation_runs():
    cfg = dx.DuplexConfig(n_blocks=2, d_branch=16, pool_factor=4,
                          branch_heads=2, use_norm=True,
                          bfp=L.BFPPolicy(enabled=False))
    params = dx.duplex_init(jax.random.PRNGKey(5), cfg, D_MODEL)
    emb = jax.random.normal(jax.random.PRNGKey(6), (1, 8, D_MODEL))
    taps = jax.random.normal(jax.random.PRNGKey(7), (2, 1, 8, D_MODEL))
    out = dx.duplex_apply(params, cfg, emb, taps, policy=P32)
    assert np.all(np.isfinite(np.asarray(out)))


def test_bfp_branch_runs_and_differs():
    cfg_bfp = dx.DuplexConfig(n_blocks=2, d_branch=16, pool_factor=4,
                              branch_heads=2,
                              bfp=L.BFPPolicy(enabled=True, group=(3, 3)))
    params, emb, taps = _setup()
    a = dx.duplex_apply(params, CFG, emb, taps, policy=P32)
    b = dx.duplex_apply(params, cfg_bfp, emb, taps, policy=P32)
    assert not np.allclose(np.asarray(a), np.asarray(b))  # quantization bites
    assert np.all(np.isfinite(np.asarray(b)))
