"""Bank-level eDRAM memory controller (repro.memory): allocator capacity
invariants, refresh-policy ordering, trace emission, and cross-validation
of the trace-driven controller against the scalar edram_energy oracle."""
import random

import pytest

from repro import sim
from repro.core import edram as ed, hwmodel as hw, lifetime as lt, \
    schedule as sc
from repro.memory import (Allocator, BankGeometry, RefreshScheduler, replay,
                          merge_traces)


def _blocks(n=6, batch=48, spatial=7, cb=48, ck=160):
    return lt.duplex_block_specs(n, batch, spatial, cb, ck)


def _iteration(temp=60.0, policy="selective", alloc="lifetime", **kw):
    return sim.run(sim.Arm(
        name="test", system=hw.SystemConfig(temp_c=temp,
                                            refresh_policy=policy,
                                            alloc_policy=alloc),
        blocks=tuple(_blocks(**kw)), reversible=True))


# ---------------------------------------------------------------- geometry

def test_geometry_matches_capacity():
    cfg = ed.EDRAMConfig()
    geom = BankGeometry.from_edram(cfg)
    assert geom.n_banks == cfg.n_banks
    assert geom.word_bits == cfg.word_bits
    # word-quantized capacity never exceeds the scalar capacity
    assert geom.total_bits <= ed.capacity_bits(cfg)
    assert geom.total_bits > 0.99 * ed.capacity_bits(cfg)
    assert geom.words_for(0) == 0
    assert geom.words_for(1) == 1
    assert geom.words_for(cfg.word_bits + 1) == 2


# --------------------------------------------------------------- allocator

@pytest.mark.parametrize("policy", ["pingpong", "first_fit", "lifetime"])
def test_allocator_never_exceeds_capacity(policy):
    cfg = ed.EDRAMConfig()
    geom = BankGeometry.from_edram(cfg)
    alloc = Allocator(geom, policy=policy,
                      retention_s=ed.retention_s(60.0))
    rng = random.Random(0)
    live = []
    for i in range(400):
        bits = rng.choice([58, 580, 5800, 58000, 580000])
        life = rng.choice([1e-7, 1e-5, 1e-3])
        p = alloc.place(f"t{i}", bits, now=i * 1e-6,
                        expected_lifetime_s=life)
        assert alloc.used_bits <= ed.capacity_bits(cfg)
        for b in alloc.banks:
            assert 0 <= b.used_words <= geom.words_per_bank
        if not p.offchip:
            live.append(f"t{i}")
        if len(live) > 5 and rng.random() < 0.5:
            alloc.free(live.pop(rng.randrange(len(live))), now=i * 1e-6)
    # the random churn above must overflow at some point: spills recorded,
    # never silent over-allocation
    assert alloc.spill_bits > 0
    assert alloc.spilled


def test_allocator_spills_whole_tensor_when_full():
    geom = BankGeometry(word_bits=58, words_per_bank=10, n_banks=2)
    alloc = Allocator(geom, policy="first_fit")
    alloc.place("big", 58 * 15, now=0.0)          # 15 of 20 words
    p = alloc.place("too_big", 58 * 8, now=0.0)   # needs 8, only 5 free
    assert p.offchip
    assert alloc.used_bits == 58 * 15
    alloc.free("big", now=1.0)
    assert alloc.used_bits == 0


def test_pingpong_rotates_and_stripes():
    geom = BankGeometry(word_bits=58, words_per_bank=100, n_banks=4)
    alloc = Allocator(geom, policy="pingpong")
    p1 = alloc.place("a", 58 * 8, now=0.0)
    p2 = alloc.place("b", 58 * 8, now=0.0)
    # striped across all banks, successive tensors start on rotated banks
    assert len(p1.spans) == 4 and len(p2.spans) == 4
    assert p1.spans[0][0] != p2.spans[0][0]


def test_lifetime_policy_confines_long_lived_tensors():
    ret = 1e-6
    geom = BankGeometry(word_bits=58, words_per_bank=100, n_banks=4)
    alloc = Allocator(geom, policy="lifetime", retention_s=ret)
    alloc.place("short", 58 * 8, now=0.0, expected_lifetime_s=ret / 10)
    p_long = alloc.place("long", 58 * 8, now=0.0, expected_lifetime_s=ret * 10)
    # long-lived data is packed densely, not striped everywhere
    assert len(p_long.spans) == 1
    p_short2 = alloc.place("short2", 58 * 8, now=0.0,
                           expected_lifetime_s=ret / 10)
    assert p_long.spans[0][0] not in [i for i, _ in p_short2.spans]


# ----------------------------------------------------------------- refresh

def test_refresh_policy_validation():
    with pytest.raises(ValueError):
        RefreshScheduler("sometimes", temp_c=60.0)
    with pytest.raises(ValueError):
        Allocator(BankGeometry(58, 10, 2), policy="best_fit")


def test_refresh_interval_is_temperature_adaptive():
    hot = RefreshScheduler("always", temp_c=100.0)
    cold = RefreshScheduler("always", temp_c=-30.0)
    assert hot.interval_s < cold.interval_s
    assert hot.interval_s == pytest.approx(ed.refresh_interval_s(100.0))


@pytest.mark.parametrize("temp", [60.0, 100.0])
@pytest.mark.parametrize("alloc", ["pingpong", "first_fit", "lifetime"])
def test_selective_between_none_and_always(temp, alloc):
    """ISSUE invariant: none ≤ selective ≤ always refresh energy."""
    reps = {pol: _iteration(temp=temp, policy=pol, alloc=alloc)
            for pol in ("none", "selective", "always")}
    r_none = reps["none"].controller.refresh_j
    r_sel = reps["selective"].controller.refresh_j
    r_alw = reps["always"].controller.refresh_j
    assert r_none == 0.0
    assert r_none <= r_sel <= r_alw
    assert r_alw > 0.0                     # data is resident ⇒ always pays


def test_selective_never_skips_over_retention_banks():
    """No silent data loss: every bank whose resident lifetime exceeds
    retention is refreshed under selective (and always)."""
    for alloc in ("pingpong", "first_fit", "lifetime"):
        rep = _iteration(temp=100.0, policy="selective", alloc=alloc)
        assert rep.controller.safe
        assert all(b.refreshed for b in rep.controller.banks
                   if b.needs_refresh)


def test_lifetime_coloring_beats_pingpong_on_selective_refresh():
    """Mixed-lifetime residency: coloring confines over-retention tensors
    to few banks, so selective refresh gets strictly cheaper."""
    sel_color = _iteration(temp=100.0, policy="selective", alloc="lifetime")
    sel_pp = _iteration(temp=100.0, policy="selective", alloc="pingpong")
    c, p = sel_color.controller, sel_pp.controller
    assert sum(b.refreshed for b in c.banks) <= sum(
        b.refreshed for b in p.banks)
    assert c.refresh_j <= p.refresh_j


# ------------------------------------------------------ trace + controller

def test_schedule_emits_consistent_trace():
    blocks = _blocks(3)
    fwd, bwd = sc.simulate_training_iteration(blocks, 1e12)
    for sim in (fwd, bwd):
        assert sim.trace, "simulate() must emit trace events"
        read = sum(e.bits for e in sim.trace if e.kind == "read")
        write = sum(e.bits for e in sim.trace if e.kind == "write")
        assert read == pytest.approx(sim.read_bits)
        assert write == pytest.approx(sim.write_bits)
        assert all(e.time >= 0 for e in sim.trace)
        # frees never precede the tensor's first event
        seen = set()
        for e in sim.trace:
            if e.kind == "free":
                assert e.tensor in seen
            seen.add(e.tensor)


def test_merge_traces_offsets_backward_timeline():
    blocks = _blocks(2)
    fwd, bwd = sc.simulate_training_iteration(blocks, 1e12)
    events, durations, total = merge_traces(fwd, bwd)
    assert total == pytest.approx(fwd.total_time + bwd.total_time)
    bwd_events = events[len(fwd.trace):]
    assert all(e.time >= fwd.total_time - 1e-18 for e in bwd_events)
    assert set(durations) >= {n for n, _, _ in fwd.schedule}


def test_controller_matches_scalar_oracle_within_5pct():
    """Replayed totals vs the scalar edram_energy oracle on the seed DuDNN
    block configs (refresh-free operating point)."""
    for nb, batch, cb, ck in [(6, 48, 48, 160), (4, 48, 32, 64),
                              (6, 1, 32, 64)]:
        rep = sim.run(sim.Arm(name="test",
                              system=hw.SystemConfig(temp_c=60.0),
                              blocks=tuple(_blocks(nb, batch, 7, cb, ck))))
        assert rep.controller is not None
        assert rep.scalar_memory_j > 0
        err = abs(rep.memory_j - rep.scalar_memory_j) / rep.scalar_memory_j
        assert err < 0.05, (rep.memory_j, rep.scalar_memory_j)


def test_controller_read_write_bits_match_schedule():
    blocks = _blocks(4)
    rep = sim.run(sim.Arm(name="test", system=hw.SystemConfig(),
                          blocks=tuple(blocks)))
    c = rep.controller
    fwd, bwd = sc.simulate_training_iteration(
        blocks, lt.array_throughput(6, 500e6,
                                    [s for b in blocks
                                     for s in (b.f1, b.f2, b.g)]),
        hw.BFP_BITS)
    total_read = fwd.read_bits + bwd.read_bits
    onchip_read = sum(b.read_bits for b in c.banks)
    assert onchip_read + c.offchip_bits >= 0
    assert onchip_read <= total_read + 1e-6
    # no spills on seed configs: all traffic stays on-chip
    assert c.spill_bits == 0
    assert onchip_read == pytest.approx(total_read)


def test_first_fit_stalls_at_least_as_much_as_striping():
    """Dense packing serializes port traffic; striping spreads it."""
    dense = _iteration(alloc="first_fit").controller
    striped = _iteration(alloc="pingpong").controller
    assert dense.stall_s >= striped.stall_s


def test_offchip_bw_is_configurable():
    """Satellite: the magic 34e9 became SystemConfig.offchip_bw_bps."""
    fr = sim.get_arm("FR+SRAM")
    slow = sim.run(fr.with_system(offchip_bw_bps=1e9))
    fast = sim.run(fr.with_system(offchip_bw_bps=1e12))
    assert slow.offchip_bits == fast.offchip_bits > 0
    assert slow.latency_s > fast.latency_s
