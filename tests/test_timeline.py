"""The closed-loop event-interleaved timing model (repro.sim.timeline):
bank idle-window queries, deadline-driven refresh placement, refresh
hiding under compute, energy invariance across timing models, the PR-2
additive golden cross-check, and the parallel grid sweep."""
import math

import pytest

from repro import sim
from repro.core import edram as ed, hwmodel as hw
from repro.core.schedule import TraceEvent
from repro.memory import BankGeometry, BankState, RefreshScheduler, replay
from repro.sim.timeline import replay_timeline

WORD = ed.EDRAMConfig().word_bits


# ------------------------------------------------- bank port busy intervals

def _bank():
    return BankState(0, BankGeometry(word_bits=58, words_per_bank=100,
                                     n_banks=1))


def test_occupy_port_merges_overlapping_intervals():
    b = _bank()
    b.occupy_port(1.0, 2.0)
    b.occupy_port(1.5, 3.0)          # overlaps -> merged
    b.occupy_port(4.0, 5.0)
    b.occupy_port(5.0, 6.0)          # adjacent -> merged
    b.occupy_port(7.0, 7.0)          # empty -> dropped
    assert b.busy_intervals == ((1.0, 3.0), (4.0, 6.0))
    assert b.busy_s == pytest.approx(4.0)


def test_idle_window_finds_earliest_gap():
    b = _bank()
    b.occupy_port(1.0, 2.0)
    b.occupy_port(4.0, 5.0)
    assert b.idle_window(0.0, 10.0, 1.0) == 0.0      # gap before first busy
    assert b.idle_window(1.5, 10.0, 1.0) == 2.0      # gap between intervals
    assert b.idle_window(1.5, 10.0, 3.0) == 5.0      # only the tail fits
    assert b.idle_window(4.2, 4.9, 0.5) is None      # inside a busy span
    assert b.idle_window(0.0, 0.5, 1.0) is None      # range shorter than need
    assert b.idle_window(3.0, 10.0, 0.0) == 3.0      # zero-length fits at lo


# ------------------------------------------- deadline-driven pulse placement

def test_place_pulses_hides_in_idle_windows_and_stalls_otherwise():
    b = _bank()
    b.peak_words = 50                 # pulse = 50 words / 100 Hz = 0.5 s
    b.occ_bit_s = 1.0
    sched = RefreshScheduler("always", temp_c=60.0, interval_s=2.0)
    # interval 1: busy [0, 2) -> no window; interval 2: idle -> hides
    b.occupy_port(0.0, 2.0)
    pulses = sched.place_pulses(b, duration_s=4.0, freq_hz=100.0)
    assert [p.hidden for p in pulses] == [False, True]
    assert pulses[0].stall_s == pytest.approx(0.5)
    assert pulses[0].start_s == pytest.approx(2.0)   # preempts at deadline
    assert pulses[1].stall_s == 0.0
    assert 2.0 <= pulses[1].start_s <= 3.5


def test_account_with_placements_splits_hidden_energy():
    b = _bank()
    b.peak_words = 50
    b.occ_bit_s = 1.0
    sched = RefreshScheduler("always", temp_c=60.0, interval_s=2.0)
    b.occupy_port(0.0, 2.0)
    placements = {0: sched.place_pulses(b, duration_s=4.0, freq_hz=100.0)}
    (d,) = sched.account([b], 4.0, 100.0, 10.0, 20.0,
                         placements=placements)
    assert d.refreshed and d.refresh_count == 2 and d.hidden_count == 1
    assert d.stall_s == pytest.approx(0.5)           # only the unhidden pulse
    assert d.refresh_hidden_j == pytest.approx(d.refresh_j / 2)
    assert b.refresh_hidden == 1


# --------------------------------------------- refresh hiding, synthetically

def _long_compute_trace(n_ops=4, dur=50e-6):
    """A long-lived resident tensor plus a few long compute ops with tiny
    traffic — ports are idle nearly all the time."""
    events = [TraceEvent(0.0, "W0", "hot", "write", WORD * 4)]
    schedule = [("W0", 0.0, 0.0)]
    for k in range(n_ops):
        t0, t1 = k * dur, (k + 1) * dur
        events.append(TraceEvent(t0, f"C{k}", "hot", "read", WORD * 4))
        events.append(TraceEvent(t1, f"C{k}", f"t{k}", "write", WORD))
        schedule.append((f"C{k}", t0, t1))
    return events, schedule, n_ops * dur


def test_refresh_hides_under_long_compute_ops():
    """ISSUE acceptance: long compute ops -> near-zero refresh_stall_s
    under the timeline model, refresh *energy* matching additive."""
    events, schedule, total = _long_compute_trace()
    cfg = ed.EDRAMConfig()
    kw = dict(temp_c=0.0, duration_s=total, refresh_policy="selective",
              alloc_policy="first_fit", freq_hz=500e6)
    tml = replay_timeline(events, cfg, op_schedule=schedule, **kw)
    add = replay(events, cfg,
                 op_durations={n: e - s for n, s, e in schedule}, **kw)
    assert add.refresh_count > 0
    assert add.refresh_stall_s > 0.0          # additive: every pulse stalls
    assert tml.refresh_stall_s == 0.0         # timeline: all pulses hide
    assert tml.refresh_count == sum(b.refresh_hidden for b in tml.banks)
    assert tml.refresh_j == pytest.approx(add.refresh_j)
    assert tml.refresh_hidden_j == pytest.approx(tml.refresh_j)
    assert tml.energy.total_j == pytest.approx(add.energy.total_j)
    assert tml.timing == "timeline" and add.timing == "additive"
    assert tml.timeline["pulses_hidden"] == tml.timeline["pulses"] > 0


def test_refresh_stalls_when_ports_never_idle():
    """A port-saturating op leaves no idle window: pulses preempt at
    their deadlines and charge full serialization."""
    cfg = ed.EDRAMConfig()
    words = 4000          # fits one bank; port time 8 us at 500 MHz, and
    #                       pulse time 8 us > the 6.7 us retention interval
    events = [TraceEvent(0.0, "BIG", "big", "write", WORD * words),
              TraceEvent(0.0, "BIG", "big", "read", WORD * words)]
    schedule = [("BIG", 0.0, 10e-6)]
    tml = replay_timeline(events, cfg, op_schedule=schedule, temp_c=60.0,
                          duration_s=10e-6, refresh_policy="always",
                          alloc_policy="first_fit", freq_hz=500e6)
    assert tml.refresh_count > 0
    assert tml.timeline["pulses_hidden"] == 0
    assert tml.refresh_stall_s > 0.0
    assert tml.refresh_hidden_j == 0.0


# ------------------------------------------------ arm-level acceptance gates

HOT = dict(temp_c=100.0, refresh_policy="selective", alloc_policy="lifetime")


def test_timeline_cuts_refresh_stall_on_hot_camel_arm():
    """Acceptance: on a Fig-24 CAMEL arm (hot operating point),
    refresh_stall_s strictly decreases vs additive while total refresh
    energy agrees within 5%."""
    arm = sim.get_arm("DuDNN+CAMEL").with_system(**HOT)
    add = sim.run(arm, timing="additive")
    tml = sim.run(arm, timing="timeline")
    assert add.refresh_stall_s > 0.0
    assert tml.refresh_stall_s < add.refresh_stall_s
    assert tml.memory["refresh_j"] == pytest.approx(
        add.memory["refresh_j"], rel=0.05)
    assert tml.refresh_hidden_j > 0.0
    assert 0 < tml.timeline["pulses_hidden"] <= tml.timeline["pulses"]
    # hiding shortens the iteration, never the energy
    assert tml.latency_s < add.latency_s
    assert tml.memory_j == pytest.approx(add.memory_j)


@pytest.mark.parametrize("name", ["DuDNN+CAMEL", "FR+SRAM"])
def test_energy_invariant_across_timing_models(name):
    """The timing model moves *time*, not energy: read/write/refresh/
    off-chip totals agree bit-for-bit between additive and timeline."""
    add = sim.run(sim.get_arm(name), timing="additive")
    tml = sim.run(sim.get_arm(name), timing="timeline")
    for field in ("read_j", "write_j", "refresh_j", "offchip_j"):
        assert tml.memory[field] == add.memory[field], field
    assert tml.memory_j == add.memory_j
    assert tml.refresh_free == add.refresh_free
    assert tml.offchip_bits == add.offchip_bits


def test_timeline_latency_composition():
    rep = sim.run(sim.get_arm("DuDNN+CAMEL").with_system(**HOT))
    assert rep.timing == "timeline"
    ctrl = rep.controller
    assert ctrl.stall_s == pytest.approx(
        ctrl.conflict_stall_s + ctrl.refresh_stall_s)
    assert rep.timeline["makespan_s"] == pytest.approx(
        rep.timeline["schedule_s"] + ctrl.conflict_stall_s)
    assert rep.latency_s == pytest.approx(
        rep.timeline["schedule_s"] + rep.stall_s
        + (rep.offchip_bits / rep.config["system"]["offchip_bw_bps"]
           if rep.offchip_bits else 0.0))
    assert any(b["busy_s"] > 0 for b in rep.memory["banks"])


def test_timeline_report_roundtrips_through_json():
    import json
    rep = sim.run(sim.get_arm("DuDNN+CAMEL").with_system(**HOT))
    back = sim.ArmReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert back == rep
    assert back.timing == "timeline"
    assert back.timeline["pulses"] == rep.timeline["pulses"]


# -------------------------------------------------- PR-2 additive cross-check

# golden numbers captured from the PR 2 additive model (seed workloads);
# timing="additive" must keep reproducing them
PR2_GOLDEN = {
    "DuDNN+CAMEL": dict(latency_s=0.0010118656680769755,
                        energy_j=5.0440828927999996e-05,
                        memory_j=4.921161727999997e-06,
                        stall_s=0.0001393277868158865,
                        offchip_bits=0.0),
    "FR+SRAM": dict(latency_s=0.016785139491461078,
                    energy_j=0.00021226073702399994,
                    memory_j=0.00010618365542399993,
                    stall_s=0.014962361806451593,
                    offchip_bits=43352064.0),
}


@pytest.mark.parametrize("name", sorted(PR2_GOLDEN))
def test_additive_reproduces_pr2_numbers_exactly(name):
    rep = sim.run(sim.get_arm(name), timing="additive")
    assert rep.timing == "additive"
    for field, want in PR2_GOLDEN[name].items():
        assert getattr(rep, field) == pytest.approx(want, rel=1e-12), field


def test_additive_timing_equals_default_pipeline():
    """timing="additive" selects exactly the PR-2 staged pipeline."""
    arm = sim.get_arm("DuDNN+CAMEL").with_system(**HOT)
    a = sim.run(arm, timing="additive")
    b = sim.run(arm, pipeline=sim.DEFAULT_PIPELINE)
    assert a.to_dict() == b.to_dict()


def test_run_validates_timing_selector():
    arm = sim.get_arm("DuDNN+CAMEL")
    with pytest.raises(ValueError, match="unknown timing"):
        sim.run(arm, timing="instant")
    with pytest.raises(ValueError, match="not both"):
        sim.run(arm, pipeline=sim.DEFAULT_PIPELINE, timing="additive")
    assert sim.DEFAULT_TIMING == "timeline"


# ----------------------------------------------------- parallel grid sweeps

def _small(name):
    return sim.get_arm(name).with_workload(n_blocks=2, batch=4,
                                           c_branch=8, c_backbone=16)


def test_sweep_grid_order_is_deterministic():
    arms = [_small("DuDNN+CAMEL"), _small("FR+SRAM")]
    reports = sim.sweep(arms, temps=(60.0, 100.0))
    assert [r.arm for r in reports] == ["DuDNN+CAMEL"] * 2 + ["FR+SRAM"] * 2
    assert [r.config["system"]["temp_c"] for r in reports] == \
        [60.0, 100.0, 60.0, 100.0]


def test_parallel_sweep_matches_sequential():
    arms = [_small("DuDNN+CAMEL"), _small("FR+SRAM")]
    kw = dict(workloads=[dict(n_blocks=2), dict(n_blocks=3)],
              temps=(60.0, 100.0))
    seq = sim.sweep(arms, **kw)
    par = sim.sweep(arms, parallel=2, **kw)
    assert len(seq) == len(par) == 8
    assert [r.to_dict() for r in seq] == [r.to_dict() for r in par]


def test_sweep_workload_axis_accepts_specs_and_dicts():
    spec = sim.WorkloadSpec(n_blocks=2, batch=4, c_branch=8, c_backbone=16)
    reports = sim.sweep([sim.get_arm("DuDNN+CAMEL")],
                        workloads=[spec, dict(n_blocks=3, batch=4,
                                              c_branch=8, c_backbone=16)])
    assert reports[0].config["workload"]["n_blocks"] == 2
    assert reports[1].config["workload"]["n_blocks"] == 3


def test_sweep_rejects_bad_timing_before_spawning():
    with pytest.raises(ValueError, match="unknown timing"):
        sim.sweep([_small("DuDNN+CAMEL")], timing="nope", parallel=2)
