"""HLO analyzer: trip-count weighting, collective accounting, dot FLOPs."""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.launch import hlo_analysis as ha


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_trip_weighted():
    """cost_analysis counts while bodies once; our analyzer multiplies."""
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 256), jnp.float32)

    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = lax.scan(body, x, None, length=10)
        return y

    txt = _hlo(f, w, x)
    mod = ha.HloModule(txt)
    expect = 2 * 8 * 256 * 256 * 10
    assert abs(mod.dot_flops() - expect) / expect < 0.01


def test_nested_scan_multipliers_compose():
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(w, x):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            c, _ = lax.scan(inner, c, None, length=5)
            return c, None
        y, _ = lax.scan(outer, x, None, length=3)
        return y

    mod = ha.HloModule(_hlo(f, w, x))
    expect = 2 * 4 * 64 * 64 * 15         # 3 × 5 iterations
    assert abs(mod.dot_flops() - expect) / expect < 0.01


def test_conditional_weighted_half():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x):
        def body(c, i):
            c = lax.cond(i < 5, lambda a: jnp.tanh(a @ a), lambda a: a, c)
            return c, None
        y, _ = lax.scan(body, x, jnp.arange(10))
        return y

    mod = ha.HloModule(_hlo(f, x))
    full = 2 * 64 * 64 * 64 * 10
    # both branches weighted 1/2 → expected ≈ half the always-execute count
    assert mod.dot_flops() == pytest.approx(full / 2, rel=0.05)


def test_collective_parsing_on_synthetic_hlo():
    txt = """
HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main.1 (p0: f32[16,32]) -> f32[16,32] {
  %p0 = f32[16,32]{1,0} parameter(0)
  %ar = f32[16,32]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = f32[64,32]{1,0} all-gather(%p0), dimensions={0}
  ROOT %out = f32[16,32]{1,0} copy(%ar)
}
"""
    c = ha.collective_bytes(txt)
    assert c["all-reduce"] == 16 * 32 * 4
    assert c["all-gather"] == 64 * 32 * 4        # result size, not shard
    assert c["total"] == (16 * 32 + 64 * 32) * 4


def test_traffic_fusion_aware_excludes_elementwise():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(x):
        return jnp.tanh(x) * 2 + 1      # pure elementwise: no dots

    mod = ha.HloModule(_hlo(f, x))
    assert mod.dot_flops() == 0
    assert mod.traffic_bytes(fusion_aware=True) <= \
        mod.traffic_bytes(fusion_aware=False)
