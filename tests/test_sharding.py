"""Sharding rules: per-arch PartitionSpecs, divisibility guards, variants."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh
from repro.models import registry

# The rules consult only mesh.shape / axis_names, so an AbstractMesh stands
# in for the 256/512-device production meshes without touching device state
# (the real meshes are exercised by launch/dryrun.py).


def _abstract_mesh(sizes, names):
    # jax <= 0.4.x takes ((name, size), ...) pairs; newer jax takes
    # (sizes, names) positionally
    try:
        return jax.sharding.AbstractMesh(sizes, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))


@pytest.fixture(scope="module")
def mesh():
    return _abstract_mesh((16, 16), ("data", "model"))


@pytest.fixture(scope="module")
def multipod():
    return _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_attention_weights_tp(mesh):
    assert sh.param_pspec("stack/sub0/attn/wq/w", (80, 8192, 8192), mesh) == \
        P(None, "data", "model")
    assert sh.param_pspec("stack/sub0/attn/wo/w", (80, 8192, 8192), mesh) == \
        P(None, "model", "data")
    assert sh.param_pspec("rem/sub0/attn/wq/w", (4096, 4096), mesh) == \
        P("data", "model")


def test_divisibility_guard_drops_axis(mesh):
    # 36-head starcoder bias: 4608 % 16 == 0 → sharded; 13 → replicated
    assert sh.param_pspec("attn/wq/b", (4608,), mesh) == P("model")
    assert sh.param_pspec("attn/wq/b", (13,), mesh) == P(None)


def test_moe_expert_parallel(mesh):
    spec = sh.param_pspec("stack/sub0/moe/wi", (48, 128, 5120, 8192), mesh)
    assert spec == P(None, "model", "data", None)
    assert sh.param_pspec("stack/sub0/moe/router/w", (48, 5120, 128),
                          mesh) == P(None, None, None)


def test_embed_fsdp_tp(mesh):
    assert sh.param_pspec("embed/table", (152064, 8192), mesh) == \
        P("model", "data")


def test_norms_replicated(mesh):
    assert sh.param_pspec("stack/sub0/norm/scale", (80, 8192), mesh) == \
        P(None, None)
    # but the SSD inner norm spans the model-sharded d_inner
    assert sh.param_pspec("stack/sub0/ssd/norm/scale", (48, 3072), mesh) == \
        P(None, "model")


def test_fsdp_pure_variant(mesh):
    # dim0 divisible by 256 → fully sharded over (data, model)
    assert sh.param_pspec("stack/sub0/attn/wq/w", (80, 8192, 8192), mesh,
                          fsdp_pure=True) == P(None, ("data", "model"), None)
    # 29568 % 256 != 0 → the other dim (8192) carries the full 256-way shard
    spec = sh.param_pspec("stack/sub0/mlp/wo/w", (80, 29568, 8192), mesh,
                          fsdp_pure=True)
    shards = 1
    for ax in spec:
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            if a is not None:
                shards *= mesh.shape[a]
    assert shards == 256, spec


def test_lru_gate_variants(mesh):
    assert sh.param_pspec("stack/sub0/lru/wr/w", (12, 4096, 4096), mesh) == \
        P(None, "model", None)
    assert sh.param_pspec("stack/sub0/lru/wr/w", (12, 4096, 4096), mesh,
                          lru_gates_colparallel=True) == \
        P(None, None, "model")


def test_batch_specs(mesh, multipod):
    assert sh.batch_pspec((256, 4096), mesh) == P("data", None)
    assert sh.batch_pspec((256, 4096), multipod) == P(("pod", "data"), None)
    # batch 1 (long_500k): nothing divides → replicated
    assert sh.batch_pspec((1, 1), mesh) == P(None, None)
    # fsdp_pure: batch over every axis
    assert sh.batch_pspec((256, 4096), mesh, include_model=True) == \
        P(("data", "model"), None)


def test_cache_specs(mesh):
    # stacked KV cache: [n_rep, B, S, KV, hd] — seq over model, batch DP
    assert sh.cache_pspec("stack/sub0/k", (80, 128, 32768, 8, 128), mesh) == \
        P(None, "data", "model", None, None)
    # ring cache position array replicated; len scalar
    assert sh.cache_pspec("stack/sub0/pos", (12, 2048), mesh) == P(None, None)
    assert sh.cache_pspec("stack/sub0/len", (12,), mesh) == P()
    # ssd state: heads over model
    assert sh.cache_pspec("stack/sub0/h", (48, 128, 48, 64, 128), mesh) == \
        P(None, "data", "model", None, None)


def test_optimizer_state_mirrors_params(mesh):
    state_path = "opt/mu/branch/blocks/f1/attn/wq/w"
    assert sh._strip(state_path) == "blocks/f1/attn/wq/w"
    assert sh.param_pspec(sh._strip(state_path), (8, 1024, 1024), mesh) == \
        P(None, "data", "model")


def test_every_arch_params_get_specs(mesh):
    """No param of any full config falls through with a bad spec rank."""
    import jax.numpy as jnp
    for name in registry.ARCHS:
        entry = registry.get(name)
        shapes = jax.eval_shape(
            lambda k: entry.module.init_params(k, entry.full),
            jax.random.PRNGKey(0))
        specs = sh.tree_pspecs(shapes, mesh, sh.param_pspec)
        flat_s, _ = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: isinstance(x, P))
        flat_x = jax.tree_util.tree_leaves(shapes)
        assert len(flat_s) == len(flat_x)
        for x, s in zip(flat_x, flat_s):
            assert len(s) <= len(x.shape), (name, x.shape, s)
            for dim, ax in zip(x.shape, s):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                assert dim % n == 0, (name, x.shape, s)
