"""Property tests for the hybrid SRAM+eDRAM :class:`MemorySystem`
(``repro.memory.tiers``): across random place/free sequences and random
tiered trace replays —

- a tensor's spans live in exactly **one** tier (partial cross-tier
  placements would split a BFP group's shared exponent from its
  mantissas),
- per-bank and per-tier occupancy never exceed capacity, and frees
  return every word,
- SRAM-resident banks never refresh (zero pulses, zero pulse energy),
- the per-tier energy summaries sum **exactly** (``==``, not approx) to
  the controller totals, under both stall models.

The concrete seeded grid always runs; when ``hypothesis`` is installed
the same properties run under its shrinker as well (the container has
none, so the suite adds no dependency on it).
"""
import math
import random

import pytest

from repro.core import edram as ed
from repro.core.schedule import TraceEvent
from repro.memory import MemorySystem, iso_area_tiers, replay
from repro.sim.timeline import replay_timeline

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - container has none
    HAVE_HYPOTHESIS = False

CFG = ed.EDRAMConfig()
WORD = CFG.word_bits
RETENTION = 3.4e-6                     # the 100 °C eDRAM floor
SRAM_BANK_BITS = 48 * 1024 * 8        # largest SRAM tier bank (s=1)


def _random_system(rng) -> MemorySystem:
    tiers = iso_area_tiers(CFG, rng.choice([0.125, 0.25, 0.5, 0.75]))
    rets = [RETENTION if t.cell == "edram" else math.inf for t in tiers]
    return MemorySystem(tiers, rets,
                        policy=rng.choice(["lifetime_tiered",
                                           "tiered_first_fit"]))


def _check_occupancy(ms: MemorySystem) -> None:
    for b in ms.banks:
        assert 0 <= b.used_words <= b.geometry.words_per_bank
    for k, t in enumerate(ms.tiers):
        assert sum(b.occupied_bits for b in ms.tier_banks(k)) \
            <= t.capacity_bits


# -------------------------------------------------- allocation properties

def _run_alloc_seed(seed: int) -> None:
    rng = random.Random(seed)
    ms = _random_system(rng)
    live: list = []
    for k in range(120):
        now = k * 1e-6
        if live and rng.random() < 0.4:
            t = live.pop(rng.randrange(len(live)))
            (ms.evict if rng.random() < 0.2 else ms.free)(t, now)
        else:
            name = f"t{k}"
            bits = float(rng.randrange(WORD, 2 * SRAM_BANK_BITS))
            ttl = rng.choice([None, RETENTION / 4, RETENTION * 100])
            p = ms.place(name, bits, now, expected_lifetime_s=ttl)
            if p.spans:
                live.append(name)
                # one tier per tensor, and tier_of_tensor agrees with
                # the spans' global bank indices
                owners = {ms.tier_of_bank(i) for i, _ in p.spans}
                assert owners == {ms.tier_of_tensor(name)}
            else:
                assert name in ms.spilled
        _check_occupancy(ms)
    for t in live:
        ms.free(t, 1.0)
    # frees return every word across every tier
    assert ms.used_bits == 0.0
    assert all(f == 0.0 for f in ms.occupancy())


@pytest.mark.parametrize("seed", range(12))
def test_fuzz_tiered_allocation_invariants(seed):
    _run_alloc_seed(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10**9))
    def test_fuzz_tiered_allocation_invariants_hypothesis(seed):
        _run_alloc_seed(seed)


# ----------------------------------------------------- replay properties

def _random_trace(rng, *, n_ops=24, n_tensors=10, duration_s=1e-3):
    """A random well-formed trace (the ``test_replay_backends`` shape,
    sized so tensors land in both tiers and some spill)."""
    dt = duration_s / n_ops
    schedule = [(f"op{k}", k * dt, k * dt + (0.0 if rng.random() < 0.15
                                             else dt))
                for k in range(n_ops)]
    events = []
    for j in range(n_tensors):
        birth = rng.randrange(n_ops)
        death = rng.randrange(birth, n_ops)
        bits = float(rng.randrange(WORD, 3 * SRAM_BANK_BITS))
        buffered = rng.random() < 0.3
        name = f"t{j}"
        out = [TraceEvent(birth * dt, f"op{birth}", name,
                          "alloc" if rng.random() < 0.2 else "write",
                          bits, buffered=buffered)]
        for _ in range(rng.randrange(0, 3)):
            k = rng.randrange(birth, death + 1)
            out.append(TraceEvent(k * dt, f"op{k}", name,
                                  "read" if rng.random() < 0.7
                                  else "write", bits, buffered=buffered))
        out.sort(key=lambda e: e.time)
        if rng.random() < 0.7:
            out.append(TraceEvent(death * dt, f"op{death}", name, "free",
                                  bits, buffered=buffered))
        events.extend(out)
    events.sort(key=lambda e: e.time)
    return events, schedule, duration_s


def _check_report(rep) -> None:
    assert rep.tiers, "tiered replay must carry per-tier summaries"
    for key in ("read_j", "write_j", "restore_j", "refresh_read_j",
                "refresh_restore_j", "refresh_count", "refresh_stall_s",
                "refresh_hidden_j"):
        assert sum(t[key] for t in rep.tiers) == getattr(rep, key), key
    assert sum(t["n_banks"] for t in rep.tiers) == len(rep.banks)
    for t in rep.tiers:
        assert t["refresh_j"] == t["refresh_read_j"] + \
            t["refresh_restore_j"]
        if t["cell"] == "sram":
            # SRAM never pulses: no refresh work, energy, or stall
            assert t["refresh_count"] == 0
            assert t["refresh_read_j"] == t["refresh_restore_j"] == 0.0
            assert t["refresh_stall_s"] == 0.0


def _run_replay_seed(seed: int) -> None:
    rng = random.Random(seed)
    events, schedule, duration_s = _random_trace(rng)
    tiers = iso_area_tiers(CFG, rng.choice([0.125, 0.25, 0.5]))
    kw = dict(temp_c=rng.choice([60.0, 100.0]), duration_s=duration_s,
              refresh_policy=rng.choice(["always", "selective"]),
              alloc_policy=rng.choice(["lifetime_tiered",
                                       "tiered_first_fit"]),
              freq_hz=500e6,
              granularity=rng.choice(["bank", "row"]),
              tiers=tiers)
    durations = {n: e - s for n, s, e in schedule}
    _check_report(replay(events, CFG, op_durations=durations, **kw))
    _check_report(replay_timeline(events, CFG, op_schedule=schedule,
                                  **kw))


@pytest.mark.parametrize("seed", range(12))
def test_fuzz_tiered_replay_invariants(seed):
    _run_replay_seed(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(min_value=0, max_value=10**9))
    def test_fuzz_tiered_replay_invariants_hypothesis(seed):
        _run_replay_seed(seed)
