"""Pallas BFP kernels vs pure-jnp oracles (interpret mode on CPU).

Shape/dtype sweeps per the kernel-testing contract; hypothesis drives the
random shape exploration at a modest example count (CPU interpret is slow).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings

from repro.kernels import ops, ref
from repro.kernels.bfp_matmul import bfp_matmul
from repro.kernels.bfp_quant import bfp_matmul_packed, bfp_quantize_pallas

INTERP = dict(interpret=True)


def _rand(key, shape, dtype=jnp.float32, scale=2.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


@pytest.mark.parametrize("m,k,n", [
    (32, 32, 32),          # single block, single group row
    (64, 96, 32),          # multi-group, uneven grid
    (100, 70, 36),         # needs padding on every dim
    (256, 128, 512),       # multi-block grid
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bfp_matmul_matches_ref(m, k, n, dtype):
    a, b = _rand(0, (m, k), dtype), _rand(1, (k, n), dtype)
    got = bfp_matmul(a, b, group=32, block_m=64, block_n=64, block_k=64, **INTERP)
    want = ref.ref_bfp_matmul(a, b, group=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("group", [8, 16, 32])
def test_bfp_matmul_group_sweep(group):
    a, b = _rand(2, (64, 64)), _rand(3, (64, 64))
    got = bfp_matmul(a, b, group=group, block_m=64, block_n=64, block_k=64, **INTERP)
    want = ref.ref_bfp_matmul(a, b, group=group)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_bfp_matmul_zero_gating_identical_result():
    a = _rand(4, (64, 64))
    a = a.at[:32, :].set(0.0)  # one all-zero operand tile
    b = _rand(5, (64, 64))
    ref_out = bfp_matmul(a, b, group=32, block_m=32, block_n=32, block_k=32,
                         skip_zero_groups=False, **INTERP)
    gated = bfp_matmul(a, b, group=32, block_m=32, block_n=32, block_k=32,
                       skip_zero_groups=True, **INTERP)
    np.testing.assert_allclose(np.asarray(gated), np.asarray(ref_out),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("m,n", [(32, 32), (96, 64), (70, 40)])
def test_bfp_quantize_pallas_matches_ref(m, n):
    x = _rand(6, (m, n), scale=3.0)
    mant, exp = bfp_quantize_pallas(x, group=32, block_m=64, block_n=64, **INTERP)
    rmant, rexp = ref.ref_bfp_quantize(x, group=32)
    # pallas output is padded to block multiples; compare the valid region
    np.testing.assert_array_equal(np.asarray(mant)[:rmant.shape[0], :rmant.shape[1]],
                                  np.asarray(rmant))
    np.testing.assert_array_equal(np.asarray(exp)[:rexp.shape[0], :rexp.shape[1]],
                                  np.asarray(rexp))


def test_bfp_matmul_packed_matches_ref():
    a, b = _rand(7, (64, 96), scale=3.0), _rand(8, (96, 64), scale=3.0)
    am, ae = ref.ref_bfp_quantize(a, group=32)
    bm_, be = ref.ref_bfp_quantize(b, group=32)
    got = bfp_matmul_packed(am, ae, bm_, be, group=32,
                            block_m=32, block_n=32, block_k=32, **INTERP)
    want = ref.ref_bfp_matmul_packed(am, ae, bm_, be, group=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_bfp_dense_grads_use_transposed_bfp():
    """bfp_dense backward == manual BFP matmuls with transposed operands."""
    cfg = ops.BFPKernelConfig(group=32, block_m=32, block_n=32, block_k=32,
                              interpret=True)
    x, w = _rand(9, (4, 8, 64)), _rand(10, (64, 32))
    g = _rand(11, (4, 8, 32))

    y, vjp = jax.vjp(lambda xx, ww: ops.bfp_dense(xx, ww, cfg), x, w)
    dx, dw = vjp(g)

    x2, g2 = x.reshape(-1, 64), g.reshape(-1, 32)
    np.testing.assert_allclose(
        np.asarray(dx), np.asarray(ref.ref_bfp_matmul(g2, w.T, group=32)
                                   ).reshape(x.shape), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(dw), np.asarray(ref.ref_bfp_matmul(x2.T, g2, group=32)),
        rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.ref_bfp_matmul(x2, w, group=32)
                                  ).reshape(4, 8, 32), rtol=1e-5, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    m=st.integers(1, 3), k=st.integers(1, 3), n=st.integers(1, 3),
    seed=st.integers(0, 1000),
)
def test_property_kernel_vs_oracle_random_blocks(m, k, n, seed):
    """Random multi-block shapes (multiples of 32) agree with the oracle."""
    a = _rand(seed, (32 * m, 32 * k))
    b = _rand(seed + 1, (32 * k, 32 * n))
    got = bfp_matmul(a, b, group=32, block_m=32, block_n=32, block_k=32, **INTERP)
    want = ref.ref_bfp_matmul(a, b, group=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
