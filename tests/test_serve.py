"""repro.serve: golden pins, policy crossovers, and pipeline plumbing.

Three layers of protection:

- **Golden pins** — one serving arm at the default operating point
  (60 °C, nominal clock, seed-0 traffic) pinned to exact values, and
  the four Fig-24 training arms pinned bit-identical to their
  pre-serving baselines (the serving substrate — ``reads_restore``,
  ``evict`` events, the ``serving`` report field — must be invisible to
  training arms).
- **Directional crossovers** — the physics the subsystem exists to
  show: ``skip`` beats ``always`` while reads outpace retention (60 °C,
  sequential sessions) and loses once retention shrinks under the
  decode gap (100 °C); expiries appear only past the retention-bound
  arrival regime; ``recompute`` pays more than ``evict`` per expiry.
- **Plumbing** — reconcile exact-equality on serving traces (bank and
  row granularity), both timings, sweep-axis subclass preservation,
  preemption, token conservation across policies, and the slot
  scheduler's REPRO_LOG-gated DEBUG lines.
"""
import math

import pytest

from repro import obs, sim
from repro.core import edram as ed
from repro.serve import (KV_POLICIES, ServeModel, TrafficSpec,
                         lower_traffic, requests, serve_arm)

# ------------------------------------------------------------- golden pins

# Serve/always at the defaults: 60 °C, FixedClock 500 MHz, seed-0
# traffic (10 requests @ 2e4/s, batch 4).  Exact values — the serving
# stack is deterministic end to end.
SERVE_ALWAYS_PIN = {
    "latency_s": 0.0008392538473060172,
    "energy_j": 4.734524585535741e-06,
    "compute_j": 4.562432e-06,
    "memory_j": 1.7209258553574078e-07,
    "stall_s": 0.0,
    "refresh_hidden_j": 1.4017268509129635e-07,
}

# the four Fig-24 training arms, pinned before repro.serve existed —
# the serving substrate must not move them by a single bit
FIG24_PINS = {
    "DuDNN+CAMEL": (0.0010118656680769748, 5.0440828927999996e-05,
                    0.00013932778681588595),
    "FR+SRAM": (0.011900566588235295, 0.00021226073702399994,
                0.01007778890322581),
    "CA+CAMEL": (0.0010118656680769748, 5.0440828927999996e-05,
                 0.00013932778681588595),
    "BO+CAMEL": (0.0010118656680769748, 5.0440828927999996e-05,
                 0.00013932778681588595),
}


def test_serve_always_golden_pin():
    rep = sim.run(sim.get_arm("Serve/always"))
    for field, want in SERVE_ALWAYS_PIN.items():
        assert getattr(rep, field) == want, field
    s = rep.serving
    assert s["tokens_served"] == 68
    assert s["prefill_tokens"] == 56
    assert s["requests_completed"] == 10
    assert s["kv_entries_evicted"] == 0


def test_fig24_arms_unchanged_by_serving_substrate():
    for name, (lat, e, stall) in FIG24_PINS.items():
        rep = sim.run(sim.get_arm(name))
        assert rep.latency_s == lat, name
        assert rep.energy_j == e, name
        assert rep.stall_s == stall, name
        assert not rep.serving, name          # training arms: empty dict
        assert "serving" not in rep.to_dict(), name


# ------------------------------------------------------------- crossovers

def _arm(policy, **traffic):
    a = sim.get_arm(f"Serve/{policy}")
    return a.with_traffic(**traffic) if traffic else a


SEQUENTIAL = dict(max_batch=1, arrival_per_s=2.0e3)


def test_skip_beats_always_at_60c():
    """Sequential sessions at 60 °C: every entry is re-read within
    retention, so read-triggered restore replaces refresh entirely."""
    always = sim.run(_arm("always", **SEQUENTIAL))
    skip = sim.run(_arm("skip", **SEQUENTIAL))
    assert skip.refresh_free          # no pulses fired, no data lost
    assert not always.refresh_free    # "always" pulses by definition
    assert skip.energy_j < always.energy_j
    assert skip.memory_j < always.memory_j


def test_always_beats_skip_at_100c():
    """At 100 °C retention (3.4 µs) drops under the decode gap: skip
    falls back to refreshing *and* still pays restore on every read."""
    always = sim.run(_arm("always", **SEQUENTIAL).with_system(temp_c=100.0))
    skip = sim.run(_arm("skip", **SEQUENTIAL).with_system(temp_c=100.0))
    assert not skip.refresh_free
    assert always.energy_j < skip.energy_j


def test_expiries_appear_with_arrival_rate():
    """Sequential low-rate traffic keeps every gap under retention (no
    expiries); a saturated batch stretches per-session gaps past it."""
    low = sim.run(_arm("evict", **SEQUENTIAL))
    high = sim.run(_arm("evict", arrival_per_s=1.0e5))
    assert low.serving["kv_entries_evicted"] == 0
    assert low.serving["reads_dropped"] == 0
    assert high.serving["kv_entries_evicted"] > 0
    assert high.serving["reads_dropped"] > 0


def test_recompute_costs_more_than_evict_at_high_rate():
    evict = sim.run(_arm("evict", arrival_per_s=1.0e5))
    rec = sim.run(_arm("recompute", arrival_per_s=1.0e5))
    assert rec.serving["kv_entries_recomputed"] > 0
    assert evict.serving["kv_entries_recomputed"] == 0
    assert rec.energy_j > evict.energy_j
    assert rec.latency_s > evict.latency_s
    # recompute preserves context, evict trades it away
    assert rec.serving["reads_dropped"] == 0
    assert evict.serving["reads_dropped"] > 0


def test_token_conservation_across_policies():
    """Every policy serves the same tokens (absent preemption): expiry
    changes *cost*, never the number of tokens decoded."""
    served = {p: sim.run(_arm(p, arrival_per_s=1.0e5)).serving
              for p in KV_POLICIES}
    tokens = {p: s["tokens_served"] for p, s in served.items()}
    assert len(set(tokens.values())) == 1, tokens
    assert all(s["requests_completed"] == 10 for s in served.values())


# --------------------------------------------------------------- plumbing

def test_serving_reconciles_exactly():
    for gran in ("bank", "row"):
        arm = sim.get_arm("Serve/skip").with_system(
            refresh_granularity=gran)
        rep = sim.run(arm, trace=True)
        res = obs.reconcile(rep.trace, rep)
        assert res.ok, (gran, res)


def test_serving_timings_and_report_roundtrip():
    rep_tl = sim.run(sim.get_arm("Serve/always"), timing="timeline")
    rep_ad = sim.run(sim.get_arm("Serve/always"), timing="additive")
    assert rep_tl.timing == "timeline" and rep_ad.timing == "additive"
    # energy accounting is shared between the two timings
    assert rep_tl.energy_j == pytest.approx(rep_ad.energy_j, rel=1e-12)
    d = rep_tl.to_dict()
    assert d["serving"]["policy"] == "always"
    rt = sim.ArmReport.from_dict(d)
    assert rt.serving == rep_tl.serving
    with pytest.raises(ValueError):
        sim.run(sim.get_arm("Serve/always"), timing="bogus")


def test_sweep_axes_preserve_serving_arm():
    reps = sim.sweep([sim.get_arm("Serve/skip")], temps=[60.0, 100.0],
                     freqs=[2.5e8, 5.0e8])
    assert len(reps) == 4
    assert all(r.serving for r in reps)
    assert {r.freq_hz for r in reps} == {2.5e8, 5.0e8}
    # slower clock stretches the trace: fewer tokens/s at 250 MHz
    by_freq = {}
    for r in reps:
        by_freq.setdefault(r.freq_hz, []).append(
            r.serving["tokens_per_s"])
    assert max(by_freq[2.5e8]) < min(by_freq[5.0e8])


def test_policy_registry_and_factory():
    assert all(f"Serve/{p}" in sim.arms() for p in KV_POLICIES)
    with pytest.raises(ValueError):
        serve_arm("lru")
    arm = sim.get_arm("Serve/always")
    assert arm.with_policy("evict").system.refresh_policy == "none"
    assert arm.system.refresh_policy == "always"
    assert not arm.system.reads_restore
    assert sim.get_arm("Serve/skip").system.reads_restore
    with pytest.raises(ValueError):
        arm.select_pipeline("bogus")


def test_preemption_churns_sessions():
    spec = dict(arrival_per_s=1.0e5, max_batch=2, preempt_after=2)
    rep = sim.run(_arm("always", **spec))
    s = rep.serving
    assert s["requests_preempted"] > 0
    assert s["requests_completed"] + s["requests_preempted"] == 10
    # preempted sessions' decoded tokens still count
    assert s["tokens_served"] > 0


def test_engine_trace_is_wellformed():
    """The lowered trace is globally time-ordered and conserves
    entries: every write is eventually freed or evicted."""
    model, spec = ServeModel(), TrafficSpec(arrival_per_s=1.0e5)
    tr = lower_traffic(model, spec, requests(spec),
                       op_seconds=lambda m: m / 1.8e10,
                       bits_per_value=58 / 9, kv_policy="evict",
                       retention_s=ed.retention_s(60.0))
    times = [ev.time for ev in tr.events]
    assert times == sorted(times)
    writes = sum(1 for ev in tr.events if ev.kind == "write")
    ends = sum(1 for ev in tr.events if ev.kind in ("free", "evict"))
    assert writes == ends
    assert tr.stats.max_lifetime_s > ed.retention_s(60.0)  # serving regime
    with pytest.raises(ValueError):
        lower_traffic(model, spec, op_seconds=lambda m: m / 1.8e10,
                      bits_per_value=58 / 9, kv_policy="lru")


def test_slot_scheduler_debug_logging(monkeypatch, capsys):
    monkeypatch.setenv("REPRO_LOG", "debug")
    sim.run(_arm("always", arrival_per_s=1.0e5, max_batch=2,
                 preempt_after=2))
    err = capsys.readouterr().err
    assert "request_admitted" in err
    assert "request_preempted" in err
    assert "session_evicted" in err
    # default threshold (warn) keeps stderr clean
    monkeypatch.delenv("REPRO_LOG")
    sim.run(_arm("always"))
    assert "request_admitted" not in capsys.readouterr().err


def test_schedule_serving_op_builders():
    """core.schedule gained serving-op builders: work-carrying ops whose
    reads/writes name KV entries (usable with the core simulator)."""
    from repro.core.schedule import decode_op, prefill_op

    p = prefill_op("p0", macs=1e5, kv_writes=["kv0.0", "kv0.1"], rate=1.8e10)
    assert p.work.macs == 1e5 and p.reads == ()
    assert p.writes == ("kv0.0", "kv0.1")
    d = decode_op("d0.0", macs=2e5, kv_reads=["kv0.0", "kv0.1"],
                  kv_writes=["kv0.2"], rate=1.8e10)
    assert d.reads == ("kv0.0", "kv0.1") and d.writes == ("kv0.2",)
    assert d.duration == pytest.approx(2e5 / 1.8e10)


def test_benchmark_suite_registered():
    from benchmarks import serve_sweep
    from benchmarks.run import SUITES
    assert SUITES["serve_sweep"] is serve_sweep.run
    ms = serve_sweep.measurements()
    assert [m["policy"] for m in ms] == list(KV_POLICIES)
    assert all(m["tokens_per_s"] > 0 for m in ms)
