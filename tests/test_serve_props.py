"""Hypothesis property tests for the serving engine (optional-dep gated
like tests/test_rows_props.py): across random traffic specs and KV
policies —

- determinism: equal specs lower to the *identical* trace (events, op
  schedule, stats), so a seeded serving arm is exactly reproducible;
- retention safety of ``skip``: when every decode gap stays under the
  retention floor, read-triggered restore keeps every bank's residency
  clock under retention — zero pulses, ``refresh_free=True``;
- token conservation: every policy decodes exactly Σ gen_len tokens
  (expiry changes cost, never the tokens served), and the evict/
  recompute expiry counters agree with their trace's event stream.
"""
import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings

from repro.serve import (KV_POLICIES, ServeModel, TrafficSpec,
                         lower_traffic, requests)

R_MAC_S = 1.8e10               # the default arm's 6×6 array @ 500 MHz
BITS = 58 / 9


def _seconds(macs: float) -> float:
    return macs / R_MAC_S


_specs = st.builds(
    TrafficSpec,
    seed=st.integers(min_value=0, max_value=2**31),
    n_requests=st.integers(min_value=1, max_value=12),
    arrival_per_s=st.sampled_from([2.0e3, 2.0e4, 1.0e5]),
    prompt_lens=st.just((4, 8)),
    gen_lens=st.just((4, 8)),
    max_batch=st.integers(min_value=1, max_value=4),
    preempt_after=st.sampled_from([None, 2]),
)


@settings(max_examples=40, deadline=None)
@given(spec=_specs, policy=st.sampled_from(KV_POLICIES),
       retention_us=st.sampled_from([3.4, 6.64, math.inf]))
def test_same_seed_identical_trace(spec, policy, retention_us):
    kw = dict(op_seconds=_seconds, bits_per_value=BITS,
              kv_policy=policy, retention_s=retention_us * 1e-6)
    a = lower_traffic(ServeModel(), spec, **kw)
    b = lower_traffic(ServeModel(), spec, **kw)
    assert a.events == b.events
    assert a.op_schedule == b.op_schedule
    assert a.duration_s == b.duration_s
    assert a.stats == b.stats


@settings(max_examples=25, deadline=None)
@given(spec=_specs.filter(lambda s: s.preempt_after is None),
       temp_c=st.sampled_from([30.0, 60.0]))
def test_read_before_retention_skips_refresh(spec, temp_c):
    """Whenever the trace's largest read-to-read gap sits under the
    retention floor, the ``skip`` arm fires zero refresh pulses."""
    from repro import sim
    from repro.core import edram as ed

    arm = (sim.get_arm("Serve/skip")
           .with_traffic(**{f.name: getattr(spec, f.name)
                            for f in spec.__dataclass_fields__.values()})
           .with_system(temp_c=temp_c))
    rep = sim.run(arm)
    retention = ed.retention_s(temp_c)
    # max inter-touch gap per tensor, from the arm's own lowered trace
    tr = lower_traffic(arm.model, arm.traffic, op_seconds=_seconds,
                       bits_per_value=BITS)
    last: dict = {}
    gap = 0.0
    for ev in tr.events:
        if ev.kind in ("write", "read"):
            if ev.tensor in last:
                gap = max(gap, ev.time - last[ev.tensor])
            last[ev.tensor] = ev.time
        elif ev.kind in ("free", "evict"):
            t0 = last.pop(ev.tensor, None)
            if t0 is not None:
                gap = max(gap, ev.time - t0)
    if gap < retention:
        assert rep.refresh_free
        assert rep.memory["refresh_count"] == 0
    else:
        assert not rep.refresh_free


@settings(max_examples=40, deadline=None)
@given(spec=_specs.filter(lambda s: s.preempt_after is None),
       retention_us=st.sampled_from([3.4, 6.64]))
def test_policies_conserve_tokens(spec, retention_us):
    expected = sum(r.gen_len for r in requests(spec))
    traces = {p: lower_traffic(ServeModel(), spec, op_seconds=_seconds,
                               bits_per_value=BITS, kv_policy=p,
                               retention_s=retention_us * 1e-6)
              for p in KV_POLICIES}
    for p, tr in traces.items():
        assert tr.stats.tokens_served == expected, p
        assert tr.stats.requests_completed == spec.n_requests, p
        # counters agree with the event stream
        evicts = sum(1 for ev in tr.events if ev.kind == "evict")
        assert tr.stats.kv_entries_evicted == evicts, p
        writes = sum(1 for ev in tr.events if ev.kind == "write")
        ends = sum(1 for ev in tr.events
                   if ev.kind in ("free", "evict"))
        assert writes == ends, p
    assert traces["always"].stats.kv_entries_evicted == 0
    assert (traces["recompute"].stats.kv_entries_recomputed
            == traces["recompute"].stats.kv_entries_evicted)
    assert traces["evict"].stats.kv_entries_recomputed == 0
