"""Unit + property tests for 2D BFP quantization (CAMEL §III-E)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings

from repro.core import bfp

jax.config.update("jax_enable_x64", False)


def test_roundtrip_exact_for_representable():
    # Values that are exactly representable with a shared exponent survive.
    # (max |x| = 8 ⇒ shared exp 3 ⇒ scale 2^-1; all entries are multiples of 0.5
    # with magnitude ≤ 15.5, hence exactly representable in 5 mantissa bits.)
    x = jnp.array([[1.0, 0.5, 3.5], [2.0, -1.5, 0.0], [4.0, 8.0, -8.0]])
    t = bfp.bfp_quantize(x, group=(3, 3))
    y = bfp.bfp_dequantize(t)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=0, atol=0)


def test_quantization_error_bound():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (48, 48))
    y = bfp.bfp_dequantize(bfp.bfp_quantize(x, group=(3, 3)))
    # max error within a group <= 1/2 ulp of the group scale = 2^(e-4)/2.
    err = jnp.abs(x - y)
    assert float(jnp.max(err)) < 0.25  # loose sanity bound for N(0,1) data
    assert float(bfp.quantization_rmse(x)) < 0.05


def test_transpose_invariance():
    """The paper's key property: Q(Wᵀ) == Q(W)ᵀ (Fig 11)."""
    key = jax.random.PRNGKey(1)
    for group in [(3, 3), (2, 2), (8, 8), (32, 32)]:
        w = jax.random.normal(key, (64, 96)) * 3.0
        qt = bfp.bfp_quantize(w.T, group=group)
        tq = bfp.bfp_quantize(w, group=group).transpose
        np.testing.assert_array_equal(np.asarray(qt.mant), np.asarray(tq.mant))
        np.testing.assert_array_equal(np.asarray(qt.exp), np.asarray(tq.exp))
        np.testing.assert_allclose(
            np.asarray(bfp.bfp_dequantize(qt)), np.asarray(bfp.bfp_dequantize(tq)))


def test_nonsquare_group_transpose_breaks():
    """1D/rectangular BFP does NOT commute with transpose — the motivation."""
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (12, 12))
    qt = bfp.bfp_dequantize(bfp.bfp_quantize(w.T, group=(1, 4)))
    tq = bfp.bfp_dequantize(bfp.bfp_quantize(w, group=(1, 4))).T
    assert not np.allclose(np.asarray(qt), np.asarray(tq))


def test_padding_and_batch_dims():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 5, 7))  # needs padding for (3,3)
    t = bfp.bfp_quantize(x, group=(3, 3))
    y = bfp.bfp_dequantize(t)
    assert y.shape == x.shape
    assert t.mant.shape == (2, 6, 9)
    assert t.exp.shape == (2, 2, 3)


def test_zero_group():
    x = jnp.zeros((6, 6))
    y = bfp.bfp_dequantize(bfp.bfp_quantize(x))
    np.testing.assert_array_equal(np.asarray(y), 0.0)


def test_saturation_large_values():
    x = jnp.full((3, 3), 1e9)
    y = bfp.bfp_dequantize(bfp.bfp_quantize(x))
    assert np.all(np.isfinite(np.asarray(y)))
    # clipped to exponent 7: max representable = 31 * 2^(7-4) = 248
    np.testing.assert_allclose(np.asarray(y), 248.0)


def test_ste_gradient_is_identity():
    x = jnp.linspace(-2, 2, 36).reshape(6, 6)
    g = jax.grad(lambda v: jnp.sum(bfp.bfp_qdq(v) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0)


def test_bits_per_value_paper_format():
    t = bfp.bfp_quantize(jnp.ones((9, 9)), group=(3, 3), mbits=5)
    assert abs(t.bits_per_value - 58 / 9) < 1e-9  # 6.44 bits, paper §III-E


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(2, 40),
    n=st.integers(2, 40),
    g=st.sampled_from([2, 3, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_property_roundtrip_error(m, n, g, seed):
    """Quantization error is bounded by half the group scale, elementwise."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (m, n))) * 4.0
    t = bfp.bfp_quantize(jnp.asarray(x), group=(g, g))
    y = np.asarray(bfp.bfp_dequantize(t))
    # reconstruct per-element bound from stored exponents
    exp = np.asarray(t.exp, dtype=np.float64)
    scale_elem = np.kron(np.exp2(exp - (t.mbits - 1)), np.ones((g, g)))[:m, :n]
    bound = scale_elem * 0.5 + 1e-12
    # elements above 31.5·scale saturate the 5-bit mantissa (error up to 1·scale)
    in_range = np.abs(x) <= 31.5 * scale_elem
    assert np.all((np.abs(x - y) <= bound) | ~in_range)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([3, 6, 9, 12]),
    k=st.sampled_from([3, 6, 9]),
    n=st.sampled_from([3, 6, 9, 15]),
    seed=st.integers(0, 2**16),
)
def test_property_matmul_close_to_f32(m, k, n, seed):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(ka, (m, k))
    b = jax.random.normal(kb, (k, n))
    exact = np.asarray(a @ b)
    q = np.asarray(bfp.bfp_matmul_ref(a, b))
    # ~5 mantissa bits ⇒ relative error per product ~3%; sum over k grows ~sqrt(k)
    tol = 0.08 * np.sqrt(k) * np.maximum(1.0, np.abs(exact).max())
    np.testing.assert_allclose(q, exact, atol=tol)
