"""The pluggable cost-model / DVFS API (repro.sim.cost): the work/time
split on ops, FixedClock golden-pinned to the PR-3 timeline results,
frequency-scaling properties (compute time ∝ 1/f, wall-clock refresh
invariants), the DVFS energy model, the sweep ``freqs`` axis, and the
``pulse_exceeds_retention`` surfacing."""
import dataclasses
import json
import math

import pytest

from repro import sim
from repro.core import edram as ed
from repro.core import schedule as sc
from repro.core.schedule import Op, OpWork, TraceEvent
from repro.sim.cost import (DVFSState, FixedClock, OperatingPoint,
                            cost_dict, op_timer, resolve_cost)
from repro.sim.timeline import replay_timeline

WORD = ed.EDRAMConfig().word_bits


# ------------------------------------------------ ops carry work, not time

def test_op_duration_is_derived_from_work():
    op = Op("X", OpWork(macs=10.0), ("a",), ("b",), rate=5.0)
    assert op.duration == pytest.approx(2.0)
    assert op.work.macs == 10.0
    # zero-work (fused) ops are free at any rate
    assert Op("Z", OpWork(), (), ()).duration == 0.0
    # MAC work without a baseline rate must fail loudly, not yield an
    # all-zero schedule
    with pytest.raises(ValueError, match="no baseline rate"):
        Op("Y", OpWork(macs=10.0), (), ()).duration


def test_op_legacy_positional_construction_still_works():
    """Pre-cost-model code built Op(name, seconds, reads, writes); the
    number is captured as an explicit duration_s pin."""
    op = Op("X", 1.5e-6, ("a",), ("b",))
    assert op.duration == pytest.approx(1.5e-6)
    assert op.duration_s == pytest.approx(1.5e-6)
    assert op.work == OpWork()
    # explicit pins win over work-based pricing in the op timer too
    fn = op_timer(OperatingPoint(freq_hz=1e6), mac_rate_s=1e6)
    assert fn(op) == pytest.approx(1.5e-6)


def test_builders_emit_mac_work():
    blocks = sim.WorkloadSpec(n_blocks=2, batch=4,
                              c_branch=8, c_backbone=16).blocks()
    ops = sc.forward_ops(blocks, 1e12)
    by_name = {op.name: op for op in ops}
    assert by_name["G0"].work.macs == blocks[0].g.macs
    assert by_name["G0"].duration == pytest.approx(blocks[0].g.macs / 1e12)
    assert by_name["ADD1_0"].work.macs == 0.0      # fused elementwise op
    # graph construction still sees durations via the property
    g = sc.dependency_graph(ops + sc.backward_ops(blocks, 1e12))
    assert g.number_of_nodes() == 2 * 16


def test_simulate_op_seconds_hook_retimes_the_schedule():
    blocks = sim.WorkloadSpec(n_blocks=2, batch=4,
                              c_branch=8, c_backbone=16).blocks()
    base = sc.simulate(sc.forward_ops(blocks, 1e12), blocks)
    slow = sc.simulate(sc.forward_ops(blocks, 1e12), blocks,
                       op_seconds=lambda op: 2.0 * op.duration)
    assert slow.total_time == pytest.approx(2.0 * base.total_time)
    assert slow.max_lifetime == pytest.approx(2.0 * base.max_lifetime)


# --------------------------------------------------------- model resolution

def test_fixedclock_resolves_system_nominal_clock():
    cfg = sim.get_arm("DuDNN+CAMEL").system
    point = resolve_cost(None, cfg)
    assert point.freq_hz == cfg.freq_hz
    assert point.compute_scale == 1.0
    assert resolve_cost(FixedClock(freq_hz=1e8), cfg).freq_hz == 1e8
    with pytest.raises(ValueError, match="positive clock"):
        FixedClock(freq_hz=0.0).resolve(cfg)


def test_dvfs_voltage_curve_and_energy_scale():
    cfg = sim.get_arm("DuDNN+CAMEL").system
    nominal = DVFSState(freq_hz=500e6).resolve(cfg)
    assert nominal.compute_scale == pytest.approx(1.0)
    half = DVFSState(freq_hz=250e6)
    # linear f-V curve with floor: V = 0.8·(0.45 + 0.55·0.5)
    assert half.voltage() == pytest.approx(0.8 * 0.725)
    assert half.resolve(cfg).compute_scale == pytest.approx(0.725 ** 2)
    # explicit vdd wins
    pinned = DVFSState(freq_hz=250e6, vdd=0.8).resolve(cfg)
    assert pinned.compute_scale == pytest.approx(1.0)
    with pytest.raises(ValueError, match="positive clock"):
        DVFSState(freq_hz=-1.0).resolve(cfg)


def test_operating_point_prices_all_work_kinds():
    point = OperatingPoint(freq_hz=100.0, offchip_bw_bps=1000.0)
    assert point.op_seconds(OpWork(macs=50.0), 10.0) == pytest.approx(5.0)
    assert point.op_seconds(OpWork(port_words=200.0), 1e12) == \
        pytest.approx(2.0)
    assert point.op_seconds(OpWork(dma_bits=3000.0), 1e12) == \
        pytest.approx(3.0)
    # an op finishes when its slowest component does
    assert point.op_seconds(
        OpWork(macs=50.0, port_words=200.0, dma_bits=3000.0),
        10.0) == pytest.approx(5.0)


def test_cost_model_serializes_into_config():
    rep = sim.run(sim.get_arm("DuDNN+CAMEL"))
    assert rep.config["cost"] == {"model": "FixedClock", "freq_hz": None}
    dv = sim.run(sim.get_arm("DuDNN+CAMEL").with_cost(
        DVFSState(freq_hz=250e6)))
    assert dv.config["cost"]["model"] == "DVFSState"
    assert dv.config["cost"]["freq_hz"] == 250e6
    assert dv.freq_hz == 250e6
    json.dumps(dv.to_dict())                   # JSON-safe
    assert cost_dict(None) == {"model": "FixedClock", "freq_hz": None}


# ------------------------------------- FixedClock ≡ PR-3 timeline (golden)

# captured from the PR-3 default pipeline (timing="timeline", seed
# workloads) immediately before the cost-model redesign; the FixedClock
# default must keep reproducing them bit-for-bit
PR3_TIMELINE_GOLDEN = {
    "DuDNN+CAMEL": dict(latency_s=0.0010118656680769748,
                        energy_j=5.0440828927999996e-05,
                        memory_j=4.921161727999997e-06,
                        stall_s=0.00013932778681588595,
                        refresh_stall_s=0.0,
                        refresh_hidden_j=0.0,
                        offchip_bits=0.0),
    "FR+SRAM": dict(latency_s=0.011900566588235295,
                    energy_j=0.00021226073702399994,
                    memory_j=0.00010618365542399993,
                    stall_s=0.01007778890322581,
                    refresh_stall_s=0.0,
                    refresh_hidden_j=0.0,
                    offchip_bits=43352064.0),
    "CA+CAMEL": dict(latency_s=0.0010118656680769748,
                     energy_j=5.0440828927999996e-05,
                     memory_j=4.921161727999997e-06,
                     stall_s=0.00013932778681588595,
                     refresh_stall_s=0.0,
                     refresh_hidden_j=0.0,
                     offchip_bits=0.0),
    "BO+CAMEL": dict(latency_s=0.0010118656680769748,
                     energy_j=5.0440828927999996e-05,
                     memory_j=4.921161727999997e-06,
                     stall_s=0.00013932778681588595,
                     refresh_stall_s=0.0,
                     refresh_hidden_j=0.0,
                     offchip_bits=0.0),
}


@pytest.mark.parametrize("name", sorted(PR3_TIMELINE_GOLDEN))
def test_fixedclock_reproduces_pr3_timeline_golden(name):
    """ISSUE acceptance: sim.run(arm) with the default FixedClock
    reproduces the PR-3 timeline reports bit-identically."""
    rep = sim.run(sim.get_arm(name))
    assert rep.timing == "timeline"
    assert rep.freq_hz == 500e6
    for field, want in PR3_TIMELINE_GOLDEN[name].items():
        assert getattr(rep, field) == pytest.approx(want, rel=1e-12), field
    # an explicit FixedClock at the nominal point is the same simulation
    explicit = sim.run(sim.get_arm(name).with_cost(FixedClock()))
    assert explicit.to_dict() == rep.to_dict()


def test_fixedclock_hot_arm_pulse_placement_golden():
    """The hot-arm hiding numbers (PR-3) under the default cost model."""
    arm = sim.get_arm("DuDNN+CAMEL").with_system(
        temp_c=100.0, refresh_policy="selective", alloc_policy="lifetime")
    rep = sim.run(arm)
    assert rep.latency_s == pytest.approx(0.001388870859287565, rel=1e-12)
    assert rep.refresh_stall_s == pytest.approx(0.0003039199999999991,
                                               rel=1e-12)
    assert (rep.timeline["pulses"], rep.timeline["pulses_hidden"]) == \
        (320, 175)


# -------------------------------------- frequency scaling (wall-clock laws)

def test_halving_frequency_doubles_compute_time_exactly():
    """Scaling frequency by k scales compute/schedule time by 1/k (and
    exactly, for a power-of-two k) while FixedClock compute energy is
    frequency-invariant."""
    base = sim.run(sim.get_arm("DuDNN+CAMEL"))
    half = sim.run(sim.get_arm("DuDNN+CAMEL").with_cost(
        FixedClock(freq_hz=250e6)))
    assert half.timeline["schedule_s"] == 2.0 * base.timeline["schedule_s"]
    assert half.max_lifetime_s == 2.0 * base.max_lifetime_s
    assert half.compute_j == base.compute_j        # no voltage scaling
    assert half.freq_hz == 250e6


def test_refresh_energy_is_wall_clock_invariant_under_scaling():
    """Retention deadlines are wall-clock: halving the clock exactly
    doubles the iteration's wall time and with it the refresh energy —
    i.e. refresh *power* (J per wall-clock second) is invariant, and the
    retention floor / refresh interval do not move."""
    hot = sim.get_arm("DuDNN+CAMEL").with_system(
        temp_c=100.0, refresh_policy="always")
    base = sim.run(hot)
    half = sim.run(hot.with_cost(FixedClock(freq_hz=250e6)))
    assert half.memory["refresh_j"] == 2.0 * base.memory["refresh_j"]
    assert half.memory["retention_s"] == base.memory["retention_s"] \
        == ed.retention_s(100.0)
    assert half.memory["interval_s"] == base.memory["interval_s"]
    # energy moved with wall time, not with the electrical constants
    assert half.memory["refresh_j"] / half.timeline["schedule_s"] == \
        pytest.approx(base.memory["refresh_j"]
                      / base.timeline["schedule_s"])


def test_refresh_free_verdict_flips_across_operating_points():
    """ISSUE headline: the refresh-free verdict is frequency-dependent —
    data lifetimes stretch with 1/f past the (fixed) retention floor."""
    arm = sim.get_arm("DuDNN+CAMEL")          # 60 °C seed point
    fast = sim.run(arm)
    slow = sim.run(arm.with_cost(FixedClock(freq_hz=125e6)))
    assert fast.refresh_free
    assert not slow.refresh_free
    assert slow.memory["refresh_j"] > 0.0
    assert slow.memory["retention_s"] == fast.memory["retention_s"]


def test_hiding_rate_degrades_as_the_clock_drops():
    """Pulse widths scale with 1/f against fixed deadlines: the hot arm
    hides fewer pulses (eventually none) as frequency falls."""
    hot = sim.get_arm("DuDNN+CAMEL").with_system(
        temp_c=100.0, alloc_policy="lifetime")
    fast = sim.run(hot)
    slow = sim.run(hot.with_cost(FixedClock(freq_hz=250e6)))
    fast_rate = fast.timeline["pulses_hidden"] / fast.timeline["pulses"]
    slow_rate = slow.timeline["pulses_hidden"] / slow.timeline["pulses"]
    assert fast_rate > slow_rate
    assert slow.pulse_exceeds_retention        # pulses outgrew the interval
    assert not fast.pulse_exceeds_retention


def test_dvfs_trades_energy_for_time():
    """DVFS at half clock: slower iteration, cheaper compute (∝ V²),
    refresh/memory accounting unchanged vs a plain underclock."""
    base = sim.run(sim.get_arm("DuDNN+CAMEL"))
    under = sim.run(sim.get_arm("DuDNN+CAMEL").with_cost(
        FixedClock(freq_hz=250e6)))
    dvfs = sim.run(sim.get_arm("DuDNN+CAMEL").with_cost(
        DVFSState(freq_hz=250e6)))
    assert dvfs.latency_s == under.latency_s
    assert dvfs.compute_j == pytest.approx(base.compute_j * 0.725 ** 2)
    assert dvfs.compute_j < base.compute_j
    assert dvfs.memory_j == under.memory_j     # macro rail not rescaled


# -------------------------------------------------- the sweep freqs axis

def _small(name):
    return sim.get_arm(name).with_workload(n_blocks=2, batch=4,
                                           c_branch=8, c_backbone=16)


def test_sweep_freqs_axis_order_and_values():
    arms = [_small("DuDNN+CAMEL"), _small("FR+SRAM")]
    reports = sim.sweep(arms, freqs=[500e6, 250e6])
    assert [r.arm for r in reports] == \
        ["DuDNN+CAMEL"] * 2 + ["FR+SRAM"] * 2
    assert [r.freq_hz for r in reports] == [500e6, 250e6, 500e6, 250e6]
    # frequency-dependent timing, wall-clock-invariant deadlines
    assert reports[1].latency_s > reports[0].latency_s
    assert reports[1].memory["retention_s"] == \
        reports[0].memory["retention_s"]


def test_sweep_freqs_accepts_cost_models():
    reports = sim.sweep([_small("DuDNN+CAMEL")],
                        freqs=[250e6, DVFSState(freq_hz=250e6)])
    fixed, dvfs = reports
    assert fixed.config["cost"]["model"] == "FixedClock"
    assert dvfs.config["cost"]["model"] == "DVFSState"
    assert fixed.latency_s == dvfs.latency_s
    assert dvfs.compute_j < fixed.compute_j


def test_parallel_freq_sweep_matches_sequential():
    """ISSUE acceptance: sweep(freqs=..., parallel=N) == sequential."""
    arms = [_small("DuDNN+CAMEL"), _small("FR+SRAM")]
    kw = dict(temps=(60.0, 100.0), freqs=(500e6, 250e6))
    seq = sim.sweep(arms, **kw)
    par = sim.sweep(arms, parallel=2, **kw)
    assert len(seq) == len(par) == 8
    assert [r.to_dict() for r in seq] == [r.to_dict() for r in par]


def test_frequency_sweep_moves_refresh_stall_and_hidden_energy():
    """ISSUE acceptance: sweep(freqs=[f1, f2]) yields frequency-dependent
    refresh_stall_s / refresh_hidden_j with retention unchanged."""
    hot = sim.get_arm("DuDNN+CAMEL").with_system(
        temp_c=100.0, alloc_policy="lifetime")
    f1, f2 = sim.sweep([hot], freqs=[500e6, 250e6])
    assert f1.refresh_stall_s != f2.refresh_stall_s
    assert f1.refresh_hidden_j != f2.refresh_hidden_j
    assert f1.memory["retention_s"] == f2.memory["retention_s"]
    assert f1.memory["interval_s"] == f2.memory["interval_s"]


# ------------------------------------------- pulse_exceeds_retention flag

def test_pulse_exceeds_retention_flag_on_saturated_bank():
    """A near-full bank at 60 °C: 8 µs pulse > 6.7 µs interval — the
    report flags the can-never-hide case instead of leaving only a
    silent per-interval stall."""
    cfg = ed.EDRAMConfig()
    words = 4000
    events = [TraceEvent(0.0, "BIG", "big", "write", WORD * words),
              TraceEvent(0.0, "BIG", "big", "read", WORD * words)]
    schedule = [("BIG", 0.0, 10e-6)]
    rep = replay_timeline(events, cfg, op_schedule=schedule, temp_c=60.0,
                          duration_s=10e-6, refresh_policy="always",
                          alloc_policy="first_fit", freq_hz=500e6)
    assert rep.pulse_exceeds_retention
    flagged = [b for b in rep.banks if b.pulse_exceeds_retention]
    assert flagged and all(b.refreshed for b in flagged)
    assert rep.timeline["pulses_hidden"] == 0
    # the same geometry with a clock fast enough to squeeze the pulse
    # under the interval is not flagged
    fast = replay_timeline(events, cfg, op_schedule=schedule, temp_c=60.0,
                           duration_s=10e-6, refresh_policy="always",
                           alloc_policy="first_fit", freq_hz=5e9)
    assert not fast.pulse_exceeds_retention


def test_pulse_flag_roundtrips_through_report_json():
    hot = sim.get_arm("DuDNN+CAMEL").with_system(
        temp_c=100.0, alloc_policy="lifetime").with_cost(
        FixedClock(freq_hz=250e6))
    rep = sim.run(hot)
    assert rep.pulse_exceeds_retention
    back = sim.ArmReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert back == rep
    assert back.pulse_exceeds_retention
    assert back.memory["pulse_exceeds_retention"]
    assert any(b["pulse_exceeds_retention"] for b in back.memory["banks"])


def test_sram_replay_reports_null_retention_and_strict_json():
    """SRAM's never-refresh floor is math.inf on the live controller but
    must serialize as null — the report's JSON form stays strict-JSON
    (no Infinity tokens)."""
    rep = sim.run(sim.get_arm("FR+SRAM"))
    assert math.isinf(rep.controller.retention_s)
    assert math.isinf(rep.controller.interval_s)
    assert rep.memory["retention_s"] is None
    assert rep.memory["interval_s"] is None
    assert not rep.pulse_exceeds_retention
    json.dumps(rep.to_dict(), allow_nan=False)     # strict JSON holds


# ----------------------------------------------------- benchmark plumbing

def test_fig24_freq_rows_surface_verdict_and_warnings(capsys):
    from benchmarks import fig24_tta_eta
    rows = fig24_tta_eta._freq_rows(None, None, [500e6, 125e6])
    tagged = [r for r in rows if isinstance(r, dict)]
    assert [r["freq_hz"] for r in tagged[:2]] == [500e6, 125e6]
    base_fast, base_slow = tagged[0]["row"], tagged[1]["row"]
    assert "refresh_free=True" in base_fast
    assert "refresh_free=False" in base_slow
    # the hot point at 125 MHz can never hide -> a structured stderr
    # warning (repro.obs.log), never a stdout row
    assert not any(isinstance(r, str) and "WARN" in r for r in rows)
    err = capsys.readouterr().err
    assert "[repro:warn] pulse_exceeds_retention" in err
    assert "arm=DuDNN+CAMEL/T100" in err


def test_bank_occupancy_hiding_row_carries_freq(capsys):
    from benchmarks import bank_occupancy
    rows: list = []
    bank_occupancy._append_hiding(rows, freq_hz=250e6)
    assert rows[0]["freq_hz"] == 250e6
    assert "_warn" not in rows[0]
    assert "f250MHz" in rows[0]["row"]
    assert len(rows) == 1                          # warning is not a row
    # 250 MHz can't hide -> structured warning on stderr
    err = capsys.readouterr().err
    assert "[repro:warn] pulse_exceeds_retention" in err
    assert "freq_mhz=250" in err
