"""Hypothesis property tests for the tensor-to-bank allocator policies
(optional-dep gated like tests/test_bfp.py): across random place/free
sequences and all three policies —

- no two live tensors ever share words (per-bank resident word counts are
  exclusive and sum exactly to the bank's used words),
- bank capacity is never exceeded,
- frees return every word (an emptied allocator is all-zeros).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings

from repro.core import edram as ed
from repro.memory import ALLOC_POLICIES, Allocator, BankGeometry

GEOM = BankGeometry(word_bits=58, words_per_bank=64, n_banks=6)

# (bits, expected lifetime) pairs; lifetimes straddle the retention floor
_RETENTION = 1e-5
_steps = st.lists(
    st.tuples(st.integers(min_value=1, max_value=58 * 96),
              st.sampled_from([_RETENTION / 10, _RETENTION * 10]),
              st.booleans()),          # free-something-afterwards flag
    min_size=1, max_size=80)


def _check_invariants(alloc: Allocator) -> None:
    for bank in alloc.banks:
        # capacity never exceeded, and words are exclusively owned: the
        # per-tensor residencies tile the bank's used words exactly
        assert 0 <= bank.used_words <= GEOM.words_per_bank
        assert sum(r.words for r in bank.resident.values()) == \
            bank.used_words


@pytest.mark.parametrize("policy", ALLOC_POLICIES)
@settings(max_examples=40, deadline=None)
@given(steps=_steps)
def test_allocator_invariants_under_churn(policy, steps):
    alloc = Allocator(GEOM, policy=policy, retention_s=_RETENTION)
    live = []
    for i, (bits, life, do_free) in enumerate(steps):
        p = alloc.place(f"t{i}", bits, now=i * 1e-6,
                        expected_lifetime_s=life)
        if p.offchip:
            # spilled whole: no words taken anywhere
            assert not p.spans
            assert f"t{i}" in alloc.spilled
        else:
            # placement covers the tensor exactly, once
            assert sum(w for _, w in p.spans) == GEOM.words_for(bits)
            assert len({b for b, _ in p.spans}) == len(p.spans)
            live.append(f"t{i}")
        _check_invariants(alloc)
        if do_free and live:
            alloc.free(live.pop(0), now=i * 1e-6)
            _check_invariants(alloc)
    # frees return all words
    for t in live:
        alloc.free(t, now=1.0)
    assert alloc.used_bits == 0
    assert all(b.used_words == 0 and not b.resident for b in alloc.banks)


@pytest.mark.parametrize("policy", ALLOC_POLICIES)
@settings(max_examples=20, deadline=None)
@given(sizes=st.lists(st.integers(min_value=1, max_value=58 * 400),
                      min_size=1, max_size=30))
def test_allocator_capacity_is_a_hard_ceiling(policy, sizes):
    """Even without frees, over-subscription spills — never over-allocates."""
    alloc = Allocator(GEOM, policy=policy,
                      retention_s=ed.retention_s(60.0))
    total_placed = 0
    for i, bits in enumerate(sizes):
        p = alloc.place(f"t{i}", bits, now=0.0)
        if not p.offchip:
            total_placed += GEOM.words_for(bits)
        assert alloc.used_bits <= GEOM.total_bits
        _check_invariants(alloc)
    assert total_placed == sum(b.used_words for b in alloc.banks)
