"""The hybrid SRAM+eDRAM tier subsystem (``repro.memory.tiers``).

Three layers of guarantees:

- **golden-pin guard** — every pre-tier arm (the four Fig-24 training
  arms and the ``Serve/*`` family), both refresh granularities, both
  temperatures, both replay backends, reproduces its committed report
  hash exactly (``data_tier_pins.json``, captured on the pre-refactor
  tree).  The placement-policy seam and the tiered replay threading are
  bit-invisible to every single-tier configuration.
- **subsystem semantics** — tier routing (``lifetime_tiered``),
  cross-tier spill fallback, iso-area geometry, the Allocator-compatible
  interface, and validation errors.
- **the mixed-cell result** — at 100 °C the registered interior split
  is refresh-free and strictly cheaper than both homogeneous endpoints
  (exact pinned floats), the per-tier energy summaries sum exactly to
  the controller totals, and hybrid reports survive a JSON round-trip.
"""
import dataclasses
import hashlib
import json
import math
import pathlib

import pytest

from repro import sim
import repro.serve  # noqa: F401  (registers the Serve/* arms)
from repro.core import hwmodel as hw
from repro.memory import (ALLOC_POLICIES, TIER_POLICIES, MemorySystem,
                          TierSpec, iso_area_tiers,
                          resolve_placement_policy, resolve_tier_policy)

PINS = json.loads((pathlib.Path(__file__).parent
                   / "data_tier_pins.json").read_text())

CFG = hw.SystemConfig().edram
RETENTION_100C = 3.4e-6                  # eDRAM floor at the hot point


# ------------------------------------------------- golden-pin guard

def _canon(report) -> str:
    """The serialized report minus ``config`` (which records the
    requested backend and so legitimately differs across the grid)."""
    d = report.to_dict()
    d.pop("config", None)
    return json.dumps(d, sort_keys=True)


@pytest.mark.parametrize("key", sorted(PINS))
def test_pretier_reports_bit_identical(key):
    """Every single-tier arm's report hash matches the pin captured
    before the placement-policy refactor and the tiered replay seam —
    byte-for-byte, bank and row granularity, python and vector."""
    name, temp, gran, backend = key.split("|")
    arm = sim.get_arm(name).with_system(temp_c=float(temp),
                                        refresh_granularity=gran,
                                        replay_backend=backend)
    sha = hashlib.sha256(_canon(sim.run(arm)).encode()).hexdigest()
    assert sha == PINS[key], f"report drifted for {key}"


# ------------------------------------------------- iso-area geometry

def test_iso_area_interior_split_geometry():
    tiers = iso_area_tiers(CFG, 0.25)
    by_cell = {t.cell: t for t in tiers}
    ed, sr = by_cell["edram"], by_cell["sram"]
    # eDRAM keeps its 12 banks at 3/4 area; SRAM gets 1/4 area at half
    # density across the baseline's 4 banks
    assert (ed.n_banks, ed.bank_kb) == (12, 24.0)
    assert (sr.n_banks, sr.bank_kb) == (4, 12.0)
    # iso-area invariant on the stock array (density_vs_sram = 2)
    assert ed.capacity_kb + 2 * sr.capacity_kb == 384.0
    # both tiers speak the same 58-bit BFP words
    assert ed.word_bits == sr.word_bits == CFG.word_bits
    # SRAM never refreshes: no pulse energy, no rows to pulse
    assert sr.refresh_read_pj_per_bit == sr.refresh_restore_pj_per_bit == 0.0


def test_iso_area_endpoints_are_homogeneous():
    (ed,) = iso_area_tiers(CFG, 0.0)
    assert (ed.cell, ed.n_banks, ed.bank_kb) == ("edram", 12, 32.0)
    (sr,) = iso_area_tiers(CFG, 1.0)
    # all-SRAM at iso-area is exactly the FR baseline's 4 x 48 KB
    assert (sr.cell, sr.n_banks, sr.bank_kb) == ("sram", 4, 48.0)


@pytest.mark.parametrize("s", (-0.1, 1.5))
def test_iso_area_rejects_out_of_range_split(s):
    with pytest.raises(ValueError):
        iso_area_tiers(CFG, s)


def test_tier_leakage_monotone_in_sram_share():
    splits = [i / 8 for i in range(9)]
    leak = [sum(t.leakage_mw for t in iso_area_tiers(CFG, s))
            for s in splits]
    assert all(b > a for a, b in zip(leak, leak[1:]))


# ------------------------------------------------- MemorySystem semantics

def _system(s=0.25, policy="lifetime_tiered"):
    tiers = iso_area_tiers(CFG, s)
    rets = [RETENTION_100C if t.cell == "edram" else math.inf
            for t in tiers]
    return MemorySystem(tiers, rets, policy=policy)


def test_lifetime_tiered_routes_by_retention():
    ms = _system()
    # sub-retention transient -> the dense eDRAM tier (tier 0)
    ms.place("act", 1e5, 0.0, expected_lifetime_s=1e-6)
    assert ms.tiers[ms.tier_of_tensor("act")].cell == "edram"
    # over-retention buffer -> the refresh-free SRAM tier
    ms.place("buf", 1e5, 0.0, expected_lifetime_s=1e-3)
    assert ms.tiers[ms.tier_of_tensor("buf")].cell == "sram"
    # unknown lifetime counts as short-lived (single-tier convention)
    ms.place("unk", 1e5, 0.0)
    assert ms.tiers[ms.tier_of_tensor("unk")].cell == "edram"


def test_cross_tier_spill_fallback():
    ms = _system()
    sram_k = next(k for k, t in enumerate(ms.tiers) if t.cell == "sram")
    sram_bits = ms.tiers[sram_k].capacity_bits
    # a long-lived tensor too big for SRAM falls through to eDRAM
    # (cross-tier fallback) instead of spilling off-chip
    p = ms.place("big", sram_bits + CFG.word_bits, 0.0,
                 expected_lifetime_s=1.0)
    assert p.spans and ms.tiers[ms.tier_of_tensor("big")].cell == "edram"
    assert ms.spill_bits == 0.0
    # bigger than every tier: whole-tensor off-chip spill, empty spans
    p2 = ms.place("huge", 10 * sum(t.capacity_bits for t in ms.tiers),
                  0.0, expected_lifetime_s=1.0)
    assert p2.spans == () and "huge" in ms.spilled
    assert ms.spill_bits > 0.0


def test_global_bank_namespace_and_occupancy():
    ms = _system()
    assert [b.index for b in ms.banks] == list(range(len(ms.banks)))
    assert all(ms.banks[i] is ms.tier_banks(ms.tier_of_bank(i))
               [i - ms.offsets[ms.tier_of_bank(i)]]
               for i in range(len(ms.banks)))
    ms.place("t", 1e6, 0.0, expected_lifetime_s=1e-6)
    spans = ms.placements["t"].spans
    assert spans and {ms.tier_of_bank(i) for i, _ in spans} == {0}
    occ = ms.occupancy()
    assert len(occ) == len(ms.banks) and all(0.0 <= f <= 1.0 for f in occ)
    used = ms.used_bits
    ms.free("t", 1.0)
    assert ms.used_bits == 0.0 < used


def test_memory_system_validation():
    tiers = iso_area_tiers(CFG, 0.25)
    with pytest.raises(ValueError, match="at least one tier"):
        MemorySystem((), [])
    with pytest.raises(ValueError, match="one retention floor per tier"):
        MemorySystem(tiers, [1e-6])
    mixed = (tiers[0], dataclasses.replace(tiers[1], word_bits=64))
    with pytest.raises(ValueError, match="share word_bits"):
        MemorySystem(mixed, [1e-6, math.inf])
    with pytest.raises(ValueError, match="unknown tier policy"):
        resolve_tier_policy("hotness")
    with pytest.raises(ValueError, match="unknown alloc policy"):
        resolve_placement_policy("buddy")
    with pytest.raises(ValueError, match="unknown cell kind"):
        TierSpec(name="x", cell="flash")
    assert ALLOC_POLICIES == ("pingpong", "first_fit", "lifetime")
    assert TIER_POLICIES == ("lifetime_tiered", "tiered_first_fit")


# ------------------------------------------------- the mixed-cell result

def _hot(arm):
    return arm.with_system(temp_c=100.0)


def test_hybrid_interior_beats_both_endpoints():
    """The pinned headline: at 100 °C the registered 0.25 split is
    refresh-free and strictly cheaper than all-eDRAM (which pays
    refresh) and all-SRAM (which pays capacity -> DRAM traffic)."""
    hyb = sim.run(_hot(sim.hybrid_arm(sim.HYBRID_SPLIT)))
    ed = sim.run(_hot(sim.get_arm("DuDNN+CAMEL")))
    sr = sim.run(_hot(sim.get_arm("FR+SRAM")))
    assert hyb.energy_j == 5.046702079999999e-05
    assert ed.energy_j == 5.150255443438304e-05
    assert sr.energy_j == 0.00021226073702399994
    assert hyb.energy_j < ed.energy_j < sr.energy_j
    assert hyb.refresh_free and hyb.memory["refresh_j"] == 0.0
    assert not ed.refresh_free
    assert ed.memory["refresh_j"] == 1.0617255063830422e-06
    assert hyb.memory["spill_bits"] == 0.0 and hyb.offchip_bits == 0.0


def test_hybrid_tier_summaries_sum_exactly_to_totals():
    rep = sim.run(_hot(sim.hybrid_arm(sim.HYBRID_SPLIT)))
    assert [t["cell"] for t in rep.tiers] == ["edram", "sram"]
    m = rep.memory
    for k in ("read_j", "write_j", "restore_j", "refresh_read_j",
              "refresh_restore_j", "refresh_stall_s", "refresh_count",
              "refresh_hidden_j"):
        assert sum(t[k] for t in rep.tiers) == m[k], k
    assert rep.tiers == tuple(m["tiers"])
    # the SRAM tier never pulses and the expensive DuDNN buffers live
    # there (non-zero traffic)
    sram = rep.tiers[1]
    assert sram["refresh_count"] == 0 and sram["refresh_read_j"] == 0.0
    assert sram["write_bits"] > 0.0


def test_hybrid_report_json_round_trip():
    rep = sim.run(_hot(sim.hybrid_arm(sim.HYBRID_SPLIT)))
    d = json.loads(json.dumps(rep.to_dict()))
    assert sim.ArmReport.from_dict(d).to_dict() == d
    # the tiers axis serializes inside the resolved config too
    assert [t["cell"] for t in d["config"]["system"]["tiers"]] \
        == ["edram", "sram"]


def test_hybrid_arm_endpoints_delegate_to_registered_arms():
    assert sim.hybrid_arm(0.0) is sim.get_arm("DuDNN+CAMEL")
    assert sim.hybrid_arm(1.0) is sim.get_arm("FR+SRAM")
    assert sim.get_arm("Hybrid+CAMEL").system.alloc_policy \
        == "lifetime_tiered"


def test_sweep_splits_axis_matches_single_runs():
    """``sim.sweep(splits=...)`` is the grid form of ``_with_split``:
    the interior point reproduces the hybrid arm's pinned energy and
    the s=0 point the plain all-eDRAM run, headline for headline."""
    arm = _hot(sim.get_arm("DuDNN+CAMEL"))
    s0, s25 = sim.sweep([arm], splits=[0.0, 0.25])
    plain = sim.run(arm)
    for field in ("energy_j", "latency_s", "refresh_stall_s",
                  "offchip_bits", "refresh_free"):
        assert getattr(s0, field) == getattr(plain, field), field
    assert s25.energy_j == 5.046702079999999e-05
    assert s25.refresh_free


def test_vector_backend_downgrades_on_tiered_config(capsys):
    arm = _hot(sim.hybrid_arm(sim.HYBRID_SPLIT)) \
        .with_system(replay_backend="vector")
    rep = sim.run(arm)
    assert "replay_backend_downgrade" in capsys.readouterr().err
    ref = sim.run(_hot(sim.hybrid_arm(sim.HYBRID_SPLIT)))
    assert rep.energy_j == ref.energy_j
    assert rep.memory == ref.memory


# ------------------------------------------------- oracle overflow term

def test_scalar_oracle_overflow_moves_streamed_traffic_offchip():
    """When the streamed transients themselves exceed on-chip capacity
    the oracle moves the overflowing share of the on-chip traffic
    through DRAM instead of going negative-budget (the PR 2 debt); on
    the stock capacity the term is exactly zero."""
    from repro.sim.pipeline import DEFAULT_PIPELINE, _scalar_memory
    arm = sim.get_arm("DuDNN+CAMEL")
    _, ctx = DEFAULT_PIPELINE.run(arm)
    mem0, off0, _ = _scalar_memory(arm, ctx)
    # shrink capacity below the streamed working set: overflow active
    tiny = arm.with_system(onchip_bits=1e4)
    mem1, off1, _ = _scalar_memory(tiny, ctx)
    assert off1 > off0 >= 0.0
    assert mem1.offchip_j > mem0.offchip_j
    assert mem1.total_j > mem0.total_j
    # and the pipeline still cross-validates end-to-end on that config
    rep = sim.run(tiny)
    assert math.isfinite(rep.oracle_rel_err)
