"""The unified arm/pipeline API (repro.sim): registry, staged pipeline,
JSON round-trip, the FR/SRAM arm through the trace-driven controller with
its scalar oracle, refresh energy split, and the hwmodel deprecation
shims."""
import dataclasses
import json
import warnings

import pytest

from repro import sim
from repro.core import edram as ed, hwmodel as hw

PAPER_ARMS = ("DuDNN+CAMEL", "FR+SRAM", "CA+CAMEL", "BO+CAMEL")


# ---------------------------------------------------------------- registry

def test_registry_has_the_four_paper_arms():
    assert set(PAPER_ARMS) <= set(sim.arms())
    assert sim.get_arm("DuDNN+CAMEL").reversible
    assert not sim.get_arm("FR+SRAM").reversible
    assert not sim.get_arm("FR+SRAM").system.use_edram
    assert sim.get_arm("CA+CAMEL").iters_to_target == sim.ITERS_CHAIN
    assert sim.get_arm("BO+CAMEL").iters_to_target is None


def test_get_arm_unknown_name_lists_registered():
    with pytest.raises(KeyError, match="DuDNN"):
        sim.get_arm("nope")


def test_register_arm_refuses_silent_overwrite():
    arm = sim.get_arm("DuDNN+CAMEL")
    with pytest.raises(ValueError, match="already registered"):
        sim.register_arm(arm)


def test_workload_spec_resolves_blocks():
    arm = sim.get_arm("DuDNN+CAMEL").with_workload(n_blocks=3, batch=8)
    blocks = arm.resolve_blocks()
    assert len(blocks) == 3 and blocks[0].f1.batch == 8
    explicit = dataclasses.replace(arm, blocks=blocks, workload=None)
    assert explicit.resolve_blocks() == blocks
    with pytest.raises(ValueError, match="unknown workload kind"):
        sim.WorkloadSpec(kind="resnet").blocks()


# ------------------------------------------------- every arm, one pipeline

@pytest.mark.parametrize("name", PAPER_ARMS)
def test_every_arm_replays_through_the_controller(name):
    rep = sim.run(sim.get_arm(name))
    assert rep.controller is not None
    assert rep.memory["mode"] == "controller"
    assert rep.energy_j > 0 and rep.latency_s > 0
    # convergence scaling: tta = latency × iters (None for BO)
    if rep.iters_to_target:
        assert rep.tta_s == pytest.approx(
            rep.latency_s * rep.iters_to_target)
    else:
        assert rep.tta_s is None and rep.eta_j is None


def test_fr_arm_controller_matches_scalar_oracle_within_5pct():
    """Acceptance: the ≤5% oracle now holds on the FR arm too (the
    workloads where the streamed working set fits on-chip — all four
    Fig 24 archs)."""
    for nb, cb, ck in [(6, 48, 160), (4, 32, 64), (5, 40, 96),
                       (6, 48, 128)]:
        rep = sim.run(sim.get_arm("FR+SRAM").with_workload(
            n_blocks=nb, batch=48, spatial=7, c_branch=cb, c_backbone=ck))
        assert rep.controller is not None
        assert rep.scalar_memory_j > 0
        assert rep.oracle_rel_err < 0.05, (nb, cb, ck, rep.oracle_rel_err)
        # the SRAM baseline really spills: whole-iteration buffers go
        # off-chip once out, once back
        assert rep.offchip_bits > 0
        assert rep.memory["spilled"]
        assert rep.memory["refresh_j"] == 0.0     # SRAM never refreshes
        assert len(rep.memory["banks"]) == rep.config["system"]["sram_banks"]


def test_fr_buffers_spill_store_plus_load():
    """Each spilled whole-iteration buffer pays exactly one store + one
    load of its bits."""
    rep = sim.run(sim.get_arm("FR+SRAM"))
    ctrl = rep.controller
    spilled = set(ctrl.spilled_tensors)
    assert spilled and all(t.startswith("sv") for t in spilled)
    blocks = sim.get_arm("FR+SRAM").resolve_blocks()
    act_bits = blocks[0].f1.batch * blocks[0].f1.c_out * \
        blocks[0].f1.width * blocks[0].f1.height * hw.FP16_BITS
    assert rep.offchip_bits == pytest.approx(2 * len(spilled) * act_bits)


def test_reversible_arms_identical_per_iteration():
    """CA/BO share DuDNN's hardware and pattern; only convergence differs."""
    dd, ca, bo = (sim.run(sim.get_arm(n))
                  for n in ("DuDNN+CAMEL", "CA+CAMEL", "BO+CAMEL"))
    assert dd.latency_s == ca.latency_s == bo.latency_s
    assert dd.energy_j == ca.energy_j == bo.energy_j
    assert ca.eta_j == pytest.approx(dd.eta_j * sim.ITERS_CHAIN
                                     / sim.ITERS_TARGET)


def test_sweep_returns_one_report_per_arm():
    arms = [sim.get_arm(n) for n in PAPER_ARMS]
    reports = sim.sweep(arms)
    assert [r.arm for r in reports] == list(PAPER_ARMS)


# ------------------------------------------------------- report round-trip

def test_report_roundtrips_through_json():
    for name in ("DuDNN+CAMEL", "FR+SRAM"):
        rep = sim.run(sim.get_arm(name))
        wire = json.dumps(rep.to_dict())
        back = sim.ArmReport.from_dict(json.loads(wire))
        assert back == rep                 # controller excluded from ==
        assert back.config["system"]["onchip_bits"] == \
            rep.config["system"]["onchip_bits"]
        assert back.memory["banks"] == rep.memory["banks"]


def test_report_config_is_fully_resolved():
    rep = sim.run(sim.get_arm("FR+SRAM").with_workload(n_blocks=4))
    cfg = rep.config
    assert cfg["workload"]["n_blocks"] == 4
    assert cfg["system"]["use_edram"] is False
    assert cfg["reversible"] is False
    # explicit blocks serialize too
    arm = sim.Arm(name="explicit", blocks=sim.WorkloadSpec().blocks(),
                  workload=None, iters_to_target=None)
    rep2 = sim.run(arm)
    assert rep2.config["blocks"][0]["f1"]["batch"] == 48


# ------------------------------------------------------- pluggable stages

def test_pipeline_stage_replacement_and_insertion():
    calls = []

    def probe(arm, ctx):
        calls.append((arm.name, ctx.controller is not None))

    pipe = sim.DEFAULT_PIPELINE.insert_after("memory", "probe", probe)
    rep = sim.run(sim.get_arm("DuDNN+CAMEL"), pipeline=pipe)
    assert calls == [("DuDNN+CAMEL", True)]
    assert rep.controller is not None

    def no_controller(arm, ctx):
        ctx.controller = None              # fall back to the scalar path

    scalar_pipe = sim.DEFAULT_PIPELINE.with_stage("memory", no_controller)
    rep2 = sim.run(sim.get_arm("DuDNN+CAMEL"), pipeline=scalar_pipe)
    assert rep2.controller is None
    assert rep2.memory["mode"] == "scalar"
    assert rep2.memory_j == pytest.approx(rep2.scalar_memory_j)

    with pytest.raises(KeyError, match="no stage"):
        sim.DEFAULT_PIPELINE.with_stage("nope", probe)


def test_use_controller_false_takes_scalar_path():
    rep = sim.run(sim.get_arm("DuDNN+CAMEL").with_system(
        use_controller=False))
    assert rep.controller is None
    assert rep.memory_j == pytest.approx(rep.scalar_memory_j)


# ------------------------------------------------------ refresh split (sat)

def test_refresh_split_defaults_preserve_aggregate():
    cfg = ed.EDRAMConfig()
    assert cfg.refresh_read_pj + cfg.refresh_restore_pj == pytest.approx(
        cfg.refresh_pj_per_bit)
    assert cfg.refresh_total_pj == pytest.approx(cfg.refresh_pj_per_bit)
    # one side given: the other is the remainder of the aggregate
    half = ed.EDRAMConfig(refresh_restore_pj_per_bit=0.015)
    assert half.refresh_read_pj == pytest.approx(0.005)
    assert half.refresh_total_pj == pytest.approx(0.020)


def _hot_always(edram=None):
    arm = sim.get_arm("DuDNN+CAMEL").with_system(
        temp_c=100.0, refresh_policy="always")
    if edram is not None:
        arm = arm.with_system(edram=edram)
    return sim.run(arm)


def test_refresh_split_threads_through_controller():
    rep = _hot_always()
    m = rep.memory
    assert m["refresh_j"] > 0
    assert m["refresh_read_j"] + m["refresh_restore_j"] == pytest.approx(
        m["refresh_j"])
    # doubling only the restore energy raises refresh cost by its share
    boosted = _hot_always(ed.EDRAMConfig(
        refresh_read_pj_per_bit=ed.EDRAMConfig().refresh_read_pj,
        refresh_restore_pj_per_bit=2 * ed.EDRAMConfig().refresh_restore_pj))
    assert boosted.memory["refresh_restore_j"] == pytest.approx(
        2 * m["refresh_restore_j"])
    assert boosted.memory["refresh_read_j"] == pytest.approx(
        m["refresh_read_j"])


# ------------------------------------------------------- deprecation shims

def test_hw_iteration_shim_warns_and_matches_sim_run():
    blocks = sim.WorkloadSpec().blocks()
    with pytest.warns(DeprecationWarning, match="repro.sim.run"):
        legacy = hw.iteration(hw.SystemConfig(), blocks, reversible=True)
    rep = sim.run(sim.Arm(name="CAMEL", system=hw.SystemConfig(),
                          blocks=blocks, workload=None,
                          iters_to_target=None))
    assert legacy.latency_s == rep.latency_s
    assert legacy.energy_j == rep.energy_j
    assert legacy.memory_j == rep.memory_j
    assert legacy.refresh_free == rep.refresh_free
    assert legacy.offchip_bits == rep.offchip_bits
    assert legacy.scalar_memory_j == rep.scalar_memory_j


def test_sram_only_shim_warns_and_matches_registry():
    with pytest.warns(DeprecationWarning, match="FR\\+SRAM"):
        legacy = hw.SRAM_ONLY
    assert legacy == sim.get_arm("FR+SRAM").system


def test_tta_eta_shim_warns_and_matches_report():
    blocks = sim.WorkloadSpec().blocks()
    with pytest.warns(DeprecationWarning, match="iters_to_target"):
        legacy = hw.tta_eta(hw.SystemConfig(), blocks, 1000)
    rep = sim.run(sim.get_arm("DuDNN+CAMEL"))
    assert legacy["tta_s"] == pytest.approx(rep.tta_s)
    assert legacy["eta_j"] == pytest.approx(rep.eta_j)


def test_sim_api_emits_no_deprecation_warnings():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        sim.run(sim.get_arm("FR+SRAM"))
        sim.run(sim.get_arm("DuDNN+CAMEL"))


def test_shim_warnings_are_attributed_to_the_caller():
    """stacklevel=2 on every shim: the DeprecationWarning must point at
    the calling file (this one), not at hwmodel.py — otherwise
    ``-W error::DeprecationWarning`` users can't find their call site."""
    blocks = sim.WorkloadSpec(n_blocks=2, batch=4,
                              c_branch=8, c_backbone=16).blocks()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always", DeprecationWarning)
        _ = hw.SRAM_ONLY
        hw.iteration(hw.SystemConfig(), blocks)
        hw.tta_eta(hw.SystemConfig(), blocks, 10)
    shim = [w for w in rec if w.category is DeprecationWarning]
    assert len(shim) == 3
    for w in shim:
        assert w.filename == __file__, (w.filename, str(w.message))
