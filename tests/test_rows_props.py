"""Hypothesis property tests for row-granular refresh pulse placement
(optional-dep gated like tests/test_bfp.py): across random bank
geometries, occupancies, and port-busy timelines —

- placed (hidden) row pulses never overlap each other or a busy
  interval recorded by ``BankState.occupy_port``,
- every pulse lands inside its own retention interval (hidden pulses
  finish by the deadline; preempting runs start exactly at it),
- hidden + stalled row counts sum to rows × ticks,
- refresh *energy* from ``RefreshScheduler.account`` is bit-identical
  across granularities (placement never enters the ∫occ·dt integral).
"""
import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings

from repro.memory import BankGeometry, BankState, RefreshScheduler

EPS = 1e-9                     # float-tolerance for interval comparisons

_geometries = st.builds(
    BankGeometry,
    word_bits=st.just(58),
    words_per_bank=st.integers(min_value=8, max_value=256),
    n_banks=st.just(1),
    rows_per_bank=st.integers(min_value=0, max_value=32),
)

# busy spans as (start, width) pairs on a [0, 10] s timeline
_busy_spans = st.lists(
    st.tuples(st.floats(min_value=0.0, max_value=9.0),
              st.floats(min_value=0.01, max_value=2.0)),
    max_size=12)


@st.composite
def _scenarios(draw):
    geom = draw(_geometries)
    peak = draw(st.integers(min_value=1, max_value=geom.words_per_bank))
    interval = draw(st.floats(min_value=0.5, max_value=4.0))
    duration = draw(st.floats(min_value=0.1, max_value=10.0))
    freq = draw(st.sampled_from([20.0, 100.0, 1000.0]))
    bank = BankState(0, geom)
    bank.peak_words = peak
    bank.occ_bit_s = float(peak * geom.word_bits) * duration
    for s, w in sorted(draw(_busy_spans)):
        bank.occupy_port(s, s + w)
    return bank, interval, duration, freq


def _overlaps(a0, a1, b0, b1):
    return a0 < b1 - EPS and b0 < a1 - EPS


@given(_scenarios())
@settings(max_examples=120, deadline=None)
def test_row_pulses_never_overlap_busy_or_each_other(scenario):
    bank, interval, duration, freq = scenario
    sched = RefreshScheduler("always", temp_c=60.0, interval_s=interval,
                             granularity="row")
    pulses = sched.place_pulses(bank, duration, freq)
    hidden = [(p.start_s, p.start_s + p.words / freq)
              for p in pulses if p.hidden]
    for i, (a0, a1) in enumerate(hidden):
        for b0, b1 in hidden[i + 1:]:
            assert not _overlaps(a0, a1, b0, b1)
        for b0, b1 in bank.busy_intervals:
            assert not _overlaps(a0, a1, b0, b1)


@given(_scenarios())
@settings(max_examples=120, deadline=None)
def test_every_pulse_lands_in_its_own_retention_interval(scenario):
    bank, interval, duration, freq = scenario
    sched = RefreshScheduler("always", temp_c=60.0, interval_s=interval,
                             granularity="row")
    for p in sched.place_pulses(bank, duration, freq):
        lo = (p.index - 1) * interval
        deadline = min(p.index * interval, duration)
        assert p.deadline_s == pytest.approx(deadline)
        if p.hidden:
            width = p.words / freq
            assert lo - EPS <= p.start_s
            assert p.start_s + width <= deadline + EPS
        else:
            # a preempting run starts exactly at its deadline and
            # charges its rows' total port time
            assert p.start_s == deadline
            assert p.stall_s == pytest.approx(p.words / freq)


@given(_scenarios())
@settings(max_examples=120, deadline=None)
def test_hidden_plus_stalled_rows_sum_to_rows_times_ticks(scenario):
    bank, interval, duration, freq = scenario
    sched = RefreshScheduler("always", temp_c=60.0, interval_s=interval,
                             granularity="row")
    pulses = sched.place_pulses(bank, duration, freq)
    rows = bank.geometry.rows_for(bank.peak_words)
    ticks = math.ceil(duration / interval)
    n_hidden = sum(p.rows for p in pulses if p.hidden)
    n_stalled = sum(p.rows for p in pulses if not p.hidden)
    assert n_hidden + n_stalled == rows * ticks
    # words are conserved per tick: every occupied word is pulsed once
    for k in range(1, ticks + 1):
        assert sum(p.words for p in pulses if p.index == k) == \
            bank.peak_words


@given(_scenarios())
@settings(max_examples=60, deadline=None)
def test_refresh_energy_is_granularity_invariant(scenario):
    bank, interval, duration, freq = scenario
    decisions = {}
    for gran in ("bank", "row"):
        b = BankState(bank.index, bank.geometry)
        b.peak_words = bank.peak_words
        b.occ_bit_s = bank.occ_bit_s
        b.max_resident_s = 10.0 * interval     # force needs_refresh
        for s, e in bank.busy_intervals:
            b.occupy_port(s, e)
        sched = RefreshScheduler("always", temp_c=60.0,
                                 interval_s=interval, retention_s=interval,
                                 granularity=gran)
        placements = {b.index: sched.place_pulses(b, duration, freq)}
        (decisions[gran],) = sched.account(
            [b], duration, freq, 10.0, 20.0, placements=placements)
    assert decisions["row"].refresh_j == decisions["bank"].refresh_j
    assert decisions["row"].refresh_read_j == \
        decisions["bank"].refresh_read_j
    assert decisions["row"].refresh_restore_j == \
        decisions["bank"].refresh_restore_j
    assert decisions["row"].stall_s <= decisions["bank"].stall_s + EPS
