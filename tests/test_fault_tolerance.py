"""Checkpointing (atomic, keep-k, integrity, elastic reshard), data pipeline
determinism, gradient compression, loop resume."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import Checkpointer, CheckpointConfig
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM, make_source
from repro.optim import compress


def _state(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "step": jnp.asarray(7, jnp.int32),
        "branch": {"w": jax.random.normal(k, (16, 32)),
                   "b": jnp.zeros((32,))},
        "opt": {"mu": {"w": jnp.ones((16, 32)) * 0.5,
                       "b": jnp.zeros((32,))}},
    }


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(CheckpointConfig(str(tmp_path)))
    state = _state()
    ck.save(7, state)
    out = ck.restore()
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    ck = Checkpointer(CheckpointConfig(str(tmp_path), keep=2))
    for s in (1, 2, 3, 4):
        ck.save(s, _state())
    assert ck.all_steps() == [3, 4]


def test_async_save_then_restore(tmp_path):
    ck = Checkpointer(CheckpointConfig(str(tmp_path)))
    ck.save(5, _state(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


def test_corrupt_blob_detected(tmp_path):
    ck = Checkpointer(CheckpointConfig(str(tmp_path)))
    ck.save(1, _state())
    d = next(Path(tmp_path).glob("step_*"))
    victim = next(d.glob("arr_*.bin"))
    victim.write_bytes(b"corrupted!")
    with pytest.raises(IOError, match="checksum"):
        ck.restore()


def test_unpublished_tmp_ignored(tmp_path):
    ck = Checkpointer(CheckpointConfig(str(tmp_path)))
    ck.save(1, _state())
    (Path(tmp_path) / "step_000000000009.tmp").mkdir()
    assert ck.latest_step() == 1


def test_elastic_restore_new_sharding(tmp_path):
    """Restore re-shards onto a different mesh than the save ran under."""
    ck = Checkpointer(CheckpointConfig(str(tmp_path)))
    state = _state()
    ck.save(3, state)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree_util.tree_map(
        lambda x: jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        state)
    out = ck.restore(shardings=shardings)
    assert out["branch"]["w"].sharding.mesh.shape == {"data": 1}
    np.testing.assert_allclose(np.asarray(out["branch"]["w"]),
                               np.asarray(state["branch"]["w"]))


# ----------------------------- data ----------------------------------------

def test_data_deterministic_and_restart_consistent():
    cfg = DataConfig(vocab=100, seq_len=32, batch_per_host=4, seed=3)
    src = SyntheticLM(cfg)
    b5a = src.batch(5)
    b5b = SyntheticLM(cfg).batch(5)     # fresh instance = restart
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert not np.array_equal(src.batch(6)["tokens"], b5a["tokens"])


def test_data_host_sharding_distinct():
    cfg = DataConfig(vocab=100, seq_len=16, batch_per_host=2)
    a = SyntheticLM(cfg, host_id=0).batch(0)
    b = SyntheticLM(cfg, host_id=1).batch(0)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=50, seq_len=16, batch_per_host=2)
    b = SyntheticLM(cfg).batch(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    assert b["tokens"].max() < 50 and b["tokens"].min() >= 0


def test_prefetcher_orders_batches():
    cfg = DataConfig(vocab=64, seq_len=8, batch_per_host=1)
    src = SyntheticLM(cfg)
    pf = Prefetcher(src, start_index=3)
    try:
        np.testing.assert_array_equal(pf.next()["tokens"],
                                      src.batch(3)["tokens"])
        np.testing.assert_array_equal(pf.next()["tokens"],
                                      src.batch(4)["tokens"])
    finally:
        pf.close()


def test_byte_corpus(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_bytes(b"the quick brown fox jumps over the lazy dog " * 50)
    cfg = DataConfig(vocab=256, seq_len=16, batch_per_host=2, kind="bytes",
                     path=str(p))
    b = make_source(cfg).batch(0)
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ----------------------------- compression ---------------------------------

def test_compression_roundtrip_error_small():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3
    y = compress.compress_decompress(x)
    rel = float(jnp.linalg.norm(x - y) / jnp.linalg.norm(x))
    assert rel < 0.01


def test_error_feedback_carries_residual():
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,))}
    r0 = {"w": jnp.zeros((64,))}
    sent, r1 = compress.error_feedback_update(g, r0)
    np.testing.assert_allclose(np.asarray(sent["w"] + r1["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)
    # residual feeds the next round: cumulative sent converges to cumulative g
    sent2, r2 = compress.error_feedback_update(g, r1)
    total_sent = np.asarray(sent["w"] + sent2["w"])
    np.testing.assert_allclose(total_sent + np.asarray(r2["w"]),
                               2 * np.asarray(g["w"]), rtol=1e-5, atol=1e-5)


def test_compressed_psum_matches_mean():
    """Under shard_map over a 1-device axis, compressed psum ≈ identity."""
    mesh = jax.make_mesh((1,), ("d",))
    x = jax.random.normal(jax.random.PRNGKey(2), (128,))

    from jax.sharding import PartitionSpec as P
    try:
        shard_map = jax.shard_map            # jax >= 0.5
    except AttributeError:
        from jax.experimental.shard_map import shard_map
    f = shard_map(lambda v: compress.compressed_psum(v, "d"),
                  mesh=mesh, in_specs=P(), out_specs=P())
    y = f(x)
    rel = float(jnp.linalg.norm(x - y) / jnp.linalg.norm(x))
    assert rel < 0.01
