"""Per-architecture smoke tests (reduced configs): one forward + one train
step + one decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L, registry

P32 = L.Policy(compute_dtype=jnp.float32)
B, S = 2, 16


def _frontend(entry, cfg, batch, key=11):
    shapes = entry.frontend_shape(cfg, batch)
    if shapes is None:
        return None
    return {k: jax.random.normal(jax.random.PRNGKey(key), v) * 0.1
            for k, v in shapes.items()}


@pytest.mark.parametrize("arch", sorted(registry.ARCHS))
def test_forward_and_grad(arch):
    entry = registry.get(arch)
    cfg = entry.smoke
    params = entry.module.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    frontend = _frontend(entry, cfg, B)

    kw = {} if frontend is None else {"frontend": frontend}
    out = entry.module.forward(params, cfg, tokens, policy=P32, **kw)
    hidden = out["hidden"]
    assert hidden.shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(hidden, dtype=np.float32)))

    logits = entry.module.lm_logits(params, cfg, hidden, P32)
    assert logits.shape[-1] >= cfg.vocab
    assert np.all(np.isfinite(np.asarray(logits[..., :cfg.vocab])))

    # one training-gradient step on the full model (family sanity)
    def loss_fn(p):
        o = entry.module.forward(p, cfg, tokens, policy=P32, **kw)
        lg = entry.module.lm_logits(p, cfg, o["hidden"], P32)
        lp = jax.nn.log_softmax(lg, axis=-1)
        tgt = jnp.roll(tokens, -1, axis=1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], -1).mean()
        return nll + 0.01 * o.get("aux", 0.0)

    val, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(val))
    gmax = max(float(jnp.max(jnp.abs(g)))
               for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gmax) and gmax > 0


@pytest.mark.parametrize("arch", sorted(registry.ARCHS))
def test_prefill_then_decode_matches_forward(arch):
    """Prefill[0:S-1] + decode step S-1 ≈ full forward's last-token logits."""
    entry = registry.get(arch)
    cfg = entry.smoke
    params = entry.module.init_params(jax.random.PRNGKey(2), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    frontend = _frontend(entry, cfg, B)
    kw = {} if frontend is None else {"frontend": frontend}

    out = entry.module.forward(params, cfg, tokens, policy=P32, **kw)
    full_logits = entry.module.lm_logits(params, cfg, out["hidden"], P32)

    pre = entry.module.prefill(params, cfg, tokens[:, :S - 1], max_len=S + 4,
                               policy=P32, cache_dtype=jnp.float32, **kw)
    np.testing.assert_allclose(
        np.asarray(pre["logits"][:, -1, :cfg.vocab]),
        np.asarray(full_logits[:, S - 2, :cfg.vocab]), rtol=2e-3, atol=2e-3)

    step_logits, cache = entry.module.decode_step(
        params, cfg, tokens[:, S - 1:S], pre["cache"], policy=P32)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0, :cfg.vocab]),
        np.asarray(full_logits[:, S - 1, :cfg.vocab]), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", sorted(registry.ARCHS))
def test_zero_init_cache_decode_runs(arch):
    """The dry-run decode entry point: fresh zero cache + one step."""
    entry = registry.get(arch)
    cfg = entry.smoke
    params = entry.module.init_params(jax.random.PRNGKey(4), cfg)
    cache = entry.module.init_cache(cfg, batch=B, max_len=S,
                                    dtype=jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = entry.module.decode_step(params, cfg, tok, cache,
                                                 policy=P32)
    assert logits.shape[0] == B
    assert np.all(np.isfinite(np.asarray(logits[..., :cfg.vocab])))


def test_registry_cells_cover_40():
    all_cells = registry.cells(include_skips=True)
    assert len(all_cells) == 40
    skipped = [c for c in all_cells if c[2] is not None]
    # 8 full-attention archs skip long_500k; ssm + hybrid run it
    assert len(skipped) == 8
    runnable = {(a, s.name) for a, s, k in all_cells if k is None}
    assert ("mamba2-780m", "long_500k") in runnable
    assert ("recurrentgemma-9b", "long_500k") in runnable
