"""Hypothesis property tests for the Chrome-trace exporter
(optional-dep gated like ``tests/test_rows_props.py``): for *arbitrary*
recorded content — any mix of span kinds, times, widths, and bank
scopes —

- the exported event list is ts-sorted with all metadata events first,
- every duration event carries its raw second-domain ``t0_s``/``t1_s``
  (with ``t0_s <= t1_s``) and every counter its ``t_s``/``value``,
- the raw-seconds JSON round-trip (``recorder_from_trace``) reproduces
  every span, counter, and the meta dict exactly (floats survive JSON
  unchanged — the µs ``ts`` values are display-only).
"""
import json

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings  # noqa: E402

from repro.obs.export import recorder_from_trace, trace_dict
from repro.obs.recorder import SpanRecorder

_times = st.floats(min_value=0.0, max_value=1e-2, allow_nan=False)
_spans = st.lists(
    st.tuples(st.sampled_from(("op", "port", "refresh", "refresh_stall",
                               "spill")),
              _times, st.floats(min_value=0.0, max_value=1e-3),
              st.integers(min_value=-1, max_value=4)),
    max_size=40)
_counters = st.lists(
    st.tuples(_times, st.floats(min_value=0.0, max_value=1.0),
              st.integers(min_value=-1, max_value=4)),
    max_size=20)


def _build(spans, counters) -> SpanRecorder:
    rec = SpanRecorder()
    for kind, t0, w, bank in spans:
        t1 = t0 if kind == "spill" else t0 + w      # spills are instants
        rec.span(kind, f"{kind}@{t0:g}", t0, t1, bank=bank,
                 stall_s=w, rows=1)
    for t, v, bank in counters:
        rec.counter("c", t, v, bank=bank)
    rec.meta.update(timing="synthetic", schedule_s=0.0)
    return rec


@settings(max_examples=60, deadline=None)
@given(spans=_spans, counters=_counters)
def test_export_sorted_and_lossless_for_any_recorder(spans, counters):
    rec = _build(spans, counters)
    trace = trace_dict(rec)
    events = trace["traceEvents"]
    first_body = next((i for i, e in enumerate(events)
                       if e["ph"] != "M"), len(events))
    assert all(e["ph"] == "M" for e in events[:first_body])
    body = events[first_body:]
    assert all(e["ph"] != "M" for e in body)
    ts = [e["ts"] for e in body]
    assert ts == sorted(ts)
    for e in body:
        if e["ph"] == "X":
            assert e["dur"] >= 0
            assert e["args"]["t0_s"] <= e["args"]["t1_s"]
        elif e["ph"] == "C":
            assert "t_s" in e["args"] and "value" in e["args"]

    back, report = recorder_from_trace(json.loads(json.dumps(trace)))
    assert report is None
    assert sorted((s.kind, s.t0, s.t1, s.bank) for s in back.spans) \
        == sorted((s.kind, s.t0, s.t1, s.bank) for s in rec.spans)
    assert sorted((c.t, c.value, c.bank) for c in back.counters) \
        == sorted((c.t, c.value, c.bank) for c in rec.counters)
    assert back.meta == rec.meta
