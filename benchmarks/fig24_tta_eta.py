"""Fig 24: TTA/ETA of the four system arms across DNN scales, via the
``repro.sim`` arm registry — every arm (including FR/SRAM) replays through
the bank-level memory controller, with the scalar closed forms as a
cross-validation oracle.

Iteration counts encode the convergence behaviour measured in
benchmarks/table2 at small scale (CA needs ~2.5× the iterations to the
target; BO does not reach it — the paper drops those bars too).

``run(timing=..., parallel=...)`` forwards the stall-model selector and
the process-pool width to ``sim.sweep`` (``benchmarks.run`` exposes them
as ``--timing`` / ``--parallel``).
"""
from __future__ import annotations

from repro import sim

# (label, branch blocks, branch ch, backbone ch) ~ paper's B-x + ResNet-y
ARCHS = [
    ("B4+R18", 4, 32, 64),
    ("B5+R34", 5, 40, 96),
    ("B6+R50", 6, 48, 160),
    ("B6+VGG16", 6, 48, 128),
]
ARMS = ("DuDNN+CAMEL", "FR+SRAM", "CA+CAMEL", "BO+CAMEL")


def run(timing=None, parallel=None) -> list:
    rows: list = []
    # one grid sweep: arms × archs, in deterministic order
    arms = [sim.get_arm(name) for name in ARMS]
    workloads = [dict(n_blocks=nb, batch=48, spatial=7,
                      c_branch=cb, c_backbone=ck)
                 for _, nb, cb, ck in ARCHS]
    flat = sim.sweep(arms, timing=timing, workloads=workloads,
                     parallel=parallel)
    by_arm = {name: flat[i * len(ARCHS):(i + 1) * len(ARCHS)]
              for i, name in enumerate(ARMS)}
    for a, (label, nb, cb, ck) in enumerate(ARCHS):
        reports = {name: by_arm[name][a] for name in ARMS}
        camel, fr, ca = (reports["DuDNN+CAMEL"], reports["FR+SRAM"],
                         reports["CA+CAMEL"])
        for name, rep in reports.items():
            tta = f"{rep.tta_s:.4e}" if rep.tta_s else "unreached"
            rows.append({
                "row": (f"fig24/{label}/{name},{rep.latency_s*1e6:.1f},"
                        f"energy_j={rep.energy_j:.4e};tta_s={tta};"
                        f"oracle_err={rep.oracle_rel_err:.4f};"
                        f"refresh_free={rep.refresh_free}"),
                "arm": name,
                "config": rep.config,
            })
        rows.append(
            f"fig24/{label},{camel.latency_s*1e6:.1f},"
            f"TTAxFR={fr.tta_s / camel.tta_s:.2f};"
            f"ETAxFR={fr.eta_j / camel.eta_j:.2f};"
            f"ETAxCA={ca.eta_j / camel.eta_j:.2f};"
            f"refresh_free={camel.refresh_free}")
    rows.append("fig24/claim,0,paper=DuDNN+CAMEL best TTA & >=2x ETA")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["row"] if isinstance(r, dict) else r)
