"""Fig 24: TTA/ETA of the four system arms across DNN scales.

Iteration counts encode the convergence behaviour measured in
benchmarks/table2 at small scale (CA needs ~2.5× the iterations to the
target; BO does not reach it — the paper drops those bars too).
"""
from __future__ import annotations

from repro.core import hwmodel as hw, lifetime as lt

# (label, branch blocks, branch ch, backbone ch) ~ paper's B-x + ResNet-y
ARCHS = [
    ("B4+R18", 4, 32, 64),
    ("B5+R34", 5, 40, 96),
    ("B6+R50", 6, 48, 160),
    ("B6+VGG16", 6, 48, 128),
]
ITERS_TARGET = 1000            # iterations for DuDNN/FR to hit the target
ITERS_CHAIN = 2500             # CA's inferior convergence (§VI-F)


def run() -> list[str]:
    rows = []
    for label, nb, cb, ck in ARCHS:
        blocks = lt.duplex_block_specs(nb, batch=48, spatial=7,
                                       c_branch=cb, c_backbone=ck)
        camel = hw.tta_eta(hw.SystemConfig(), blocks, ITERS_TARGET,
                           reversible=True)
        fr = hw.tta_eta(hw.SRAM_ONLY, blocks, ITERS_TARGET,
                        reversible=False)
        ca = hw.tta_eta(hw.SystemConfig(), blocks, ITERS_CHAIN,
                        reversible=True)
        tta_x = fr["tta_s"] / camel["tta_s"]
        eta_x = fr["eta_j"] / camel["eta_j"]
        rows.append(
            f"fig24/{label},{camel['iteration'].latency_s*1e6:.1f},"
            f"TTAxFR={tta_x:.2f};ETAxFR={eta_x:.2f};"
            f"ETAxCA={ca['eta_j']/camel['eta_j']:.2f};"
            f"refresh_free={camel['iteration'].refresh_free}")
    rows.append("fig24/claim,0,paper=DuDNN+CAMEL best TTA & >=2x ETA")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
