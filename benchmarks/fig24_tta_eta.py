"""Fig 24: TTA/ETA of the four system arms across DNN scales, via the
``repro.sim`` arm registry — every arm (including FR/SRAM) replays through
the bank-level memory controller, with the scalar closed forms as a
cross-validation oracle.

Iteration counts encode the convergence behaviour measured in
benchmarks/table2 at small scale (CA needs ~2.5× the iterations to the
target; BO does not reach it — the paper drops those bars too).

``run(timing=..., parallel=...)`` forwards the stall-model selector and
the process-pool width to ``sim.sweep`` (``benchmarks.run`` exposes them
as ``--timing`` / ``--parallel``).  ``run(freqs=[...])`` (``--freq``)
adds a frequency sweep of the CAMEL arm at the nominal and hot operating
points: op time scales with 1/f while retention deadlines stay
wall-clock, so the rows show the refresh hiding rate and the
refresh-free verdict flipping across operating points; a bank whose
pulse outlasts its retention interval triggers a structured
``pulse_exceeds_retention`` warning on stderr (``repro.obs.log`` — set
``REPRO_LOG`` to tune the threshold).  ``run(granularity="row")``
(``--granularity row``) switches every simulated arm to row-granular
refresh pulses: the hot/slow points hide refresh row by row (rows and
hiding fraction surfaced per row record), refresh *energy* is unchanged,
and only banks whose single-row pulse outlasts the interval still warn.

``run(trace_dir=...)`` (``--trace DIR``) additionally captures a
flight-recorder trace per arm — the four registry arms plus the hot
``DuDNN+CAMEL/T100`` point — reconciles each against its report, and
writes Chrome Trace Event JSON (one ``<arm>.trace.json`` per arm; open
in Perfetto, validate with ``tools/check_trace.py``).
"""
from __future__ import annotations

import dataclasses
import pathlib

from repro import obs, sim
from repro.obs import log

# (label, branch blocks, branch ch, backbone ch) ~ paper's B-x + ResNet-y
ARCHS = [
    ("B4+R18", 4, 32, 64),
    ("B5+R34", 5, 40, 96),
    ("B6+R50", 6, 48, 160),
    ("B6+VGG16", 6, 48, 128),
]
ARMS = ("DuDNN+CAMEL", "FR+SRAM", "CA+CAMEL", "BO+CAMEL")


def _freq_rows(timing, parallel, freqs, granularity=None) -> list:
    """The operating-point sweep: DuDNN+CAMEL at 60 °C and 100 °C across
    ``freqs``; one row per (point, frequency) plus warning rows.
    ``granularity`` switches the refresh pulse unit (``--granularity
    row`` emits per-wordline pulses — the hot/slow points hide refresh
    row by row instead of flagging ``pulse_exceeds_retention``)."""
    freqs = list(freqs)            # consumed twice: sweep + row indexing
    base = sim.get_arm("DuDNN+CAMEL")
    if granularity is not None:
        base = base.with_system(refresh_granularity=granularity)
    points = [
        base,
        dataclasses.replace(
            base.with_system(temp_c=100.0, alloc_policy="lifetime"),
            name="DuDNN+CAMEL/T100"),
    ]
    flat = sim.sweep(points, timing=timing, freqs=freqs,
                     parallel=parallel)
    rows: list = []
    for i, arm in enumerate(points):
        for j, _ in enumerate(freqs):
            rep = flat[i * len(freqs) + j]
            tl = rep.timeline or {}
            pulses, hidden = tl.get("pulses", 0), tl.get("pulses_hidden", 0)
            tag = f"fig24/freq/{arm.name}/f{rep.freq_hz / 1e6:g}MHz"
            rows.append({
                "row": (f"{tag},{rep.latency_s*1e6:.1f},"
                        f"refresh_free={rep.refresh_free};"
                        f"hidden={hidden}/{pulses};"
                        f"refresh_stall_us={rep.refresh_stall_s*1e6:.2f};"
                        f"refresh_hidden_j={rep.refresh_hidden_j:.3e};"
                        f"energy_j={rep.energy_j:.4e};"
                        f"granularity={rep.memory['granularity']};"
                        f"rows_refreshed={rep.rows_refreshed};"
                        f"pulse_exceeds_retention="
                        f"{rep.pulse_exceeds_retention}"),
                "arm": rep.arm,
                "freq_hz": rep.freq_hz,
                "granularity": rep.memory["granularity"],
                "refresh_stall_s": rep.refresh_stall_s,
                "rows_refreshed": rep.rows_refreshed,
                "config": rep.config,
            })
            if rep.pulse_exceeds_retention:
                log.warn("pulse_exceeds_retention", arm=arm.name,
                         freq_mhz=rep.freq_hz / 1e6,
                         granularity=rep.memory["granularity"],
                         detail="refresh pulse outlasts the retention "
                                "interval on >=1 bank; refresh there "
                                "can never hide")
    return rows


def _trace_arms(granularity=None) -> list:
    """The arms ``--trace`` captures: the four registry arms plus the hot
    100 °C CAMEL point (lifetime allocation), as in the freq sweep."""
    arms = [sim.get_arm(name) for name in ARMS]
    arms.append(dataclasses.replace(
        sim.get_arm("DuDNN+CAMEL").with_system(
            temp_c=100.0, alloc_policy="lifetime"),
        name="DuDNN+CAMEL/T100"))
    if granularity is not None:
        arms = [a.with_system(refresh_granularity=granularity)
                for a in arms]
    return arms


def _trace_rows(trace_dir, granularity=None) -> list:
    """Flight-recorder captures: one traced timeline run per arm,
    reconciled span-vs-report, exported as ``DIR/<arm>.trace.json``.
    Always runs ``timing="timeline"`` — reconciliation is defined
    against the timeline model's span stream."""
    out = pathlib.Path(trace_dir)
    out.mkdir(parents=True, exist_ok=True)
    rows: list = []
    for arm in _trace_arms(granularity):
        rep = sim.run(arm, trace=True, timing="timeline")
        res = obs.reconcile(rep.trace, rep)
        path = out / (arm.name.replace("/", "_") + ".trace.json")
        obs.export_chrome_trace(rep.trace, path, report=rep)
        if not res.ok:
            log.error("trace_reconcile_mismatch", arm=arm.name,
                      detail=str(res))
        rows.append({
            "row": (f"fig24/trace/{arm.name},0,"
                    f"file={path.name};spans={len(rep.trace.spans)};"
                    f"counters={len(rep.trace.counters)};"
                    f"reconciled={res.ok}"),
            "arm": arm.name,
            "trace_file": str(path),
            "reconciled": res.ok,
        })
    return rows


def run(timing=None, parallel=None, freqs=None, granularity=None,
        trace_dir=None) -> list:
    rows: list = []
    # one grid sweep: arms × archs, in deterministic order
    arms = [sim.get_arm(name) for name in ARMS]
    if granularity is not None:
        arms = [a.with_system(refresh_granularity=granularity)
                for a in arms]
    workloads = [dict(n_blocks=nb, batch=48, spatial=7,
                      c_branch=cb, c_backbone=ck)
                 for _, nb, cb, ck in ARCHS]
    flat = sim.sweep(arms, timing=timing, workloads=workloads,
                     parallel=parallel)
    by_arm = {name: flat[i * len(ARCHS):(i + 1) * len(ARCHS)]
              for i, name in enumerate(ARMS)}
    for a, (label, nb, cb, ck) in enumerate(ARCHS):
        reports = {name: by_arm[name][a] for name in ARMS}
        camel, fr, ca = (reports["DuDNN+CAMEL"], reports["FR+SRAM"],
                         reports["CA+CAMEL"])
        for name, rep in reports.items():
            tta = f"{rep.tta_s:.4e}" if rep.tta_s else "unreached"
            rows.append({
                "row": (f"fig24/{label}/{name},{rep.latency_s*1e6:.1f},"
                        f"energy_j={rep.energy_j:.4e};tta_s={tta};"
                        f"oracle_err={rep.oracle_rel_err:.4f};"
                        f"refresh_free={rep.refresh_free}"),
                "arm": name,
                "config": rep.config,
            })
        rows.append(
            f"fig24/{label},{camel.latency_s*1e6:.1f},"
            f"TTAxFR={fr.tta_s / camel.tta_s:.2f};"
            f"ETAxFR={fr.eta_j / camel.eta_j:.2f};"
            f"ETAxCA={ca.eta_j / camel.eta_j:.2f};"
            f"refresh_free={camel.refresh_free}")
    if freqs:
        rows += _freq_rows(timing, parallel, freqs, granularity)
    if trace_dir is not None:
        rows += _trace_rows(trace_dir, granularity)
    rows.append("fig24/claim,0,paper=DuDNN+CAMEL best TTA & >=2x ETA")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["row"] if isinstance(r, dict) else r)
