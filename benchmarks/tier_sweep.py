"""Iso-area SRAM:eDRAM tier sweep — the mixed-cell tradeoff as CSV rows.

Sweeps the ``Hybrid+CAMEL`` arm family (``repro.sim.hybrid``) over the
SRAM area share ``s`` at the hot 100 °C operating point, where the
all-eDRAM ``DuDNN+CAMEL`` endpoint pays refresh (3.4 µs retention) and
the all-SRAM ``FR+SRAM`` endpoint pays capacity (half the density, DRAM
spills).  Each grid point replaces the bank array with
``repro.memory.tiers.iso_area_tiers(cfg, s)`` — a refresh-free SRAM
tier and a dense eDRAM tier at equal silicon area — under the
``lifetime_tiered`` routing policy (MCAIMem, arXiv 2312.03559):
over-retention tensors to SRAM, transients to eDRAM.

The three claims ``tools/check_tier_sweep.py`` gates CI on:

- **leakage is monotone in s** — SRAM cells leak more per kB, so static
  power rises with the SRAM share (1.536 + 0.96·s mW on the stock
  geometry), independent of workload;
- **refresh → 0 as s → 1** — once every over-retention tensor fits the
  SRAM tier the eDRAM banks hold only sub-retention transients and the
  lifetime scheduler skips every pulse;
- **an interior split beats both endpoints on energy** — the hybrid
  keeps (most of) eDRAM's density and traffic efficiency while paying
  zero refresh.

The endpoints delegate to the registered homogeneous arms themselves
(``hybrid_arm(0.0) is get_arm("DuDNN+CAMEL")``), so endpoint rows match
the existing Fig-24 records exactly by construction.

Rows: ``tier_sweep/s<split>,us_per_iter,energy_j=...;refresh_j=...;
leakage_mw=...;refresh_free=...;sram_kb=...;edram_kb=...``

The committed record lives in ``BENCH_tiers.json`` (repo root);
re-measure and append with::

    PYTHONPATH=src python -m benchmarks.tier_sweep --update

``--json PATH`` writes the measurement grid for the CI gate::

    PYTHONPATH=src python -m benchmarks.tier_sweep --json tiers.json
    python tools/check_tier_sweep.py tiers.json
"""
from __future__ import annotations

import json
import pathlib
import time

from repro import sim
from repro.core import hwmodel as hw
from repro.memory.tiers import iso_area_tiers

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_tiers.json"

# SRAM area-share axis: homogeneous endpoints + the interior continuum
# (0.25 is the registered Hybrid+CAMEL split)
SPLITS = (0.0, 0.125, 0.25, 0.5, 0.75, 1.0)
# the hot operating point — retention 3.4 µs, where refresh actually
# costs the all-eDRAM endpoint something worth trading area against
TEMP_C = 100.0


def _tier_kb(s: float) -> tuple:
    """(sram_kb, edram_kb) capacity of the iso-area split ``s`` on the
    stock geometry — from the tier specs themselves, so the row always
    reflects what :func:`repro.memory.tiers.iso_area_tiers` built."""
    tiers = iso_area_tiers(hw.SystemConfig().edram, s)
    by_cell = {t.cell: t.capacity_kb for t in tiers}
    return by_cell.get("sram", 0.0), by_cell.get("edram", 0.0)


def _leakage_mw(s: float) -> float:
    """Static tier leakage (mW) at split ``s`` — workload-independent,
    strictly increasing in the SRAM share (the monotone CI check)."""
    return sum(t.leakage_mw for t in iso_area_tiers(hw.SystemConfig()
                                                    .edram, s))


def measurements(splits=SPLITS, temp_c: float = TEMP_C,
                 timing=None, parallel=None) -> list:
    """One record per split: the hybrid arm's headline numbers plus the
    tier geometry that produced them."""
    arms = [sim.hybrid_arm(s) for s in splits]
    flat = sim.sweep(arms, timing=timing, temps=[temp_c],
                     parallel=parallel)
    out = []
    for s, rep in zip(splits, flat):
        sram_kb, edram_kb = _tier_kb(s)
        out.append({
            "split": float(s),
            "arm": rep.arm,
            "energy_j": rep.energy_j,
            "refresh_j": rep.memory["refresh_j"],
            "refresh_free": rep.refresh_free,
            "leakage_mw": _leakage_mw(s),
            "latency_s": rep.latency_s,
            "offchip_bits": rep.offchip_bits,
            "sram_kb": sram_kb,
            "edram_kb": edram_kb,
        })
    return out


def run(timing=None, parallel=None) -> list:
    rows: list = []
    ms = measurements(timing=timing, parallel=parallel)
    for m in ms:
        rows.append({
            "row": (f"tier_sweep/s{m['split']:g},"
                    f"{m['latency_s'] * 1e6:.2f},"
                    f"energy_j={m['energy_j']:.4e};"
                    f"refresh_j={m['refresh_j']:.4e};"
                    f"leakage_mw={m['leakage_mw']:.3f};"
                    f"refresh_free={m['refresh_free']};"
                    f"sram_kb={m['sram_kb']:g};"
                    f"edram_kb={m['edram_kb']:g}"),
            "arm": m["arm"],
            "split": m["split"],
            "energy_j": m["energy_j"],
            "temp_c": TEMP_C,
        })
    interior = min((m for m in ms if 0.0 < m["split"] < 1.0),
                   key=lambda m: m["energy_j"])
    lo, hi = ms[0], ms[-1]
    rows.append(f"tier_sweep/claim,0,paper=mixed SRAM+eDRAM beats both "
                f"homogeneous endpoints at iso-area; "
                f"best_interior=s{interior['split']:g}"
                f"@{interior['energy_j']:.4e}J;"
                f"edram_endpoint={lo['energy_j']:.4e}J;"
                f"sram_endpoint={hi['energy_j']:.4e}J")
    return rows


def update_bench(path=BENCH_PATH) -> dict:
    """Append today's measurement grid to the committed trajectory file."""
    path = pathlib.Path(path)
    data = (json.loads(path.read_text()) if path.exists()
            else {"benchmark": "tier_sweep",
                  "workload": {"arm": "Hybrid+CAMEL family (DuDNN "
                                      "workload)",
                               "temp_c": TEMP_C,
                               "splits": list(SPLITS)},
                  "records": []})
    record = {"date": time.strftime("%Y-%m-%d"),
              "measurements": measurements()}
    data["records"].append(record)
    path.write_text(json.dumps(data, indent=1) + "\n")
    return record


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help=f"append a record to {BENCH_PATH.name}")
    ap.add_argument("--json", metavar="PATH",
                    help="write the measurement grid as JSON (for "
                         "tools/check_tier_sweep.py)")
    ap.add_argument("--splits", default=None,
                    help="comma-separated SRAM shares (default "
                         + ",".join(f"{s:g}" for s in SPLITS) + ")")
    args = ap.parse_args()
    splits = (tuple(float(x) for x in args.splits.split(","))
              if args.splits else SPLITS)
    if args.update:
        rec = update_bench()
        print(f"appended {rec['date']} record to {BENCH_PATH}")
    if args.json:
        grid = {"benchmark": "tier_sweep", "temp_c": TEMP_C,
                "measurements": measurements(splits)}
        pathlib.Path(args.json).write_text(json.dumps(grid, indent=1)
                                           + "\n")
        print(f"wrote {args.json}")
    for r in run():
        print(r["row"] if isinstance(r, dict) else r)
