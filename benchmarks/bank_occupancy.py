"""Bank-level controller benchmark: per-bank occupancy, refresh counts,
and the selective-vs-always refresh energy delta (CAMEL §V, Figs 17/23/24)
across the Table III array sizes.

Each row simulates one training iteration of a seed DuDNN config
(B6 + ResNet-50-scale backbone, batch 48) through the ``repro.sim``
pipeline — the trace replays through ``repro.memory`` — and
cross-validates the controller totals against the scalar ``edram_energy``
oracle at the refresh-free operating point.

``run(timing=...)`` selects the memory stall model; the
``refresh_hiding`` row always compares both (timeline must strictly cut
refresh stall vs additive at identical refresh energy).
``run(freqs=[...])`` (``--freq``) re-runs the hiding comparison at each
operating point — pulse widths scale with 1/f against wall-clock
retention deadlines, so the hiding rate degrades as the clock drops and
a structured ``pulse_exceeds_retention`` warning goes to stderr
(``repro.obs.log``) once a bank's pulse outlasts its retention interval.

``run(granularity="row")`` (``--granularity row``) switches the per-arm
rows to row-granular refresh pulses; independently, the
``row_refresh`` row always compares the two granularities at the hot
operating point (row stall must never exceed bank stall, refresh energy
must match exactly).
"""
from __future__ import annotations

from repro import sim
from repro.core import hwmodel as hw
from repro.obs import log

# seed DuDNN block configs (Table III / Fig 23-24 scale)
CONFIGS = [
    ("B6+R50", 6, 48, 48, 160),
    ("B4+R18", 4, 48, 32, 64),
]
ARRAYS = (6, 10, 12)           # Table III sweep
TEMPS = (60.0, 100.0)          # refresh-free point + mixed-lifetime point


def _arm(label: str, workload: sim.WorkloadSpec, **system) -> sim.Arm:
    return sim.Arm(name=label, system=hw.SystemConfig(**system),
                   workload=workload, reversible=True, iters_to_target=None)


def _row_refresh_row(freq_hz=None, bank=None) -> dict:
    """Row-granular vs bank-granular refresh at the hot operating point:
    row granularity must never stall more than bank granularity and must
    keep refresh energy bit-identical (placement moves time, not the
    ∫occ·dt integral the energy charges).  ``bank`` reuses an already
    simulated bank-granularity timeline report (``_append_hiding``
    returns one) instead of re-running the pipeline."""
    base = sim.get_arm("DuDNN+CAMEL").with_system(
        temp_c=100.0, refresh_policy="selective", alloc_policy="lifetime")
    if freq_hz is not None:
        base = base.with_cost(sim.FixedClock(freq_hz=freq_hz))
    if bank is None:
        bank = sim.run(base)
    row = sim.run(base.with_system(refresh_granularity="row"))
    tag = "bank_occupancy/row_refresh/T100" + (
        f"/f{row.freq_hz / 1e6:g}MHz" if freq_hz is not None else "")
    return {
        "row": (f"{tag},{row.latency_s*1e6:.1f},"
                f"bank_refresh_stall_us={bank.refresh_stall_s*1e6:.2f};"
                f"row_refresh_stall_us={row.refresh_stall_s*1e6:.2f};"
                f"rows_refreshed={row.rows_refreshed};"
                f"row_hidden_frac={row.row_hidden_frac:.3f};"
                f"stall_le_bank="         # ≤ up to float rounding
                f"{row.refresh_stall_s <= bank.refresh_stall_s * (1 + 1e-9) + 1e-18};"
                f"refresh_j_equal="
                f"{row.memory['refresh_j'] == bank.memory['refresh_j']};"
                f"bank_flags_exceeds={bank.pulse_exceeds_retention};"
                f"row_flags_exceeds={row.pulse_exceeds_retention}"),
        "arm": "DuDNN+CAMEL",
        "freq_hz": row.freq_hz,
        "granularity": "row",
        "refresh_stall_s": row.refresh_stall_s,
        "rows_refreshed": row.rows_refreshed,
        "config": row.config,
    }


def _hiding_row(freq_hz=None, granularity=None) -> tuple:
    """Refresh hiding at the hot operating point: the timeline model must
    cut refresh stall vs additive at (bit-)identical refresh energy —
    this row always runs both timings to compare.  ``freq_hz`` re-prices
    the op schedule at another clock (retention deadlines stay
    wall-clock), so hiding degrades as the clock drops.  Returns
    ``(row dict, timeline ArmReport)`` so callers can reuse the
    simulation."""
    arm = sim.get_arm("DuDNN+CAMEL").with_system(
        temp_c=100.0, refresh_policy="selective", alloc_policy="lifetime",
        refresh_granularity=granularity or "bank")
    if freq_hz is not None:
        arm = arm.with_cost(sim.FixedClock(freq_hz=freq_hz))
    add = sim.run(arm, timing="additive")
    tml = sim.run(arm, timing="timeline")
    dj = abs(tml.memory["refresh_j"] - add.memory["refresh_j"])
    rel = dj / add.memory["refresh_j"] if add.memory["refresh_j"] else 0.0
    tag = "bank_occupancy/refresh_hiding/T100" + (
        f"/f{tml.freq_hz / 1e6:g}MHz" if freq_hz is not None else "")
    return ({
        "row": (f"{tag},"
                f"{tml.latency_s*1e6:.1f},"
                f"additive_refresh_stall_us={add.refresh_stall_s*1e6:.2f};"
                f"timeline_refresh_stall_us={tml.refresh_stall_s*1e6:.2f};"
                f"hidden={tml.timeline['pulses_hidden']}"
                f"/{tml.timeline['pulses']};"
                f"hidden_j={tml.refresh_hidden_j:.3e};"
                f"stall_decreases="
                f"{tml.refresh_stall_s < add.refresh_stall_s};"
                f"refresh_j_rel_err={rel:.4f};"
                f"pulse_exceeds_retention={tml.pulse_exceeds_retention}"),
        "arm": "DuDNN+CAMEL",
        "freq_hz": tml.freq_hz,
        "config": tml.config,
    }, tml)


def _append_hiding(rows: list, freq_hz=None, granularity=None):
    """One hiding row (+ a structured stderr warning when a bank's pulse
    can never hide inside its retention interval).  Returns the timeline
    ``ArmReport`` the row was built from."""
    row, rep = _hiding_row(freq_hz, granularity)
    rows.append(row)
    if rep.pulse_exceeds_retention:
        log.warn("pulse_exceeds_retention", arm=rep.arm,
                 freq_mhz=rep.freq_hz / 1e6,
                 granularity=rep.memory["granularity"],
                 detail="refresh pulse outlasts the retention interval "
                        "on >=1 bank; refresh there can never hide")
    return rep


def run(timing=None, freqs=None, granularity=None) -> list:
    gran = granularity or "bank"
    rows: list = []
    for label, nb, batch, cb, ck in CONFIGS:
        wl = sim.WorkloadSpec(n_blocks=nb, batch=batch, spatial=7,
                              c_branch=cb, c_backbone=ck)
        for array in ARRAYS:
            for temp in TEMPS:
                per_policy = {
                    pol: sim.run(_arm(label, wl, array=array, temp_c=temp,
                                      refresh_policy=pol,
                                      refresh_granularity=gran,
                                      alloc_policy="lifetime"),
                                 timing=timing)
                    for pol in ("none", "selective", "always")}
                sel = per_policy["selective"].memory
                alw = per_policy["always"].memory
                non = per_policy["none"].memory
                banks = sel["banks"]
                occ = [b["peak_occupancy"] for b in banks]
                needs = sum(1 for b in banks if b["needs_refresh"])
                refreshed = sum(1 for b in banks if b["refreshed"])
                delta = alw["refresh_j"] - sel["refresh_j"]
                rows.append({
                    "row": (
                        f"bank_occupancy/{label}/a{array}/T{temp:.0f},"
                        f"{per_policy['selective'].latency_s*1e6:.1f},"
                        f"occ_min={min(occ):.2f};occ_max={max(occ):.2f};"
                        f"needs_refresh={needs}/12;refreshed={refreshed};"
                        f"refresh_count={sel['refresh_count']};"
                        f"sel_refresh_j={sel['refresh_j']:.3e};"
                        f"always_refresh_j={alw['refresh_j']:.3e};"
                        f"delta_j={delta:.3e};"
                        f"sel_lt_always={sel['refresh_j'] < alw['refresh_j']};"
                        f"sel_ge_none={sel['refresh_j'] >= non['refresh_j']};"
                        f"safe={sel['safe']}"),
                    "arm": label,
                    "config": per_policy["selective"].config,
                })
        # oracle cross-validation at the refresh-free point: the replayed
        # totals must match the scalar edram_energy arithmetic within 5%
        rep = sim.run(_arm(label, wl, temp_c=60.0), timing=timing)
        rows.append({
            "row": (f"bank_occupancy/{label}/oracle,0,"
                    f"controller_j={rep.memory_j:.4e};"
                    f"scalar_j={rep.scalar_memory_j:.4e};"
                    f"rel_err={rep.oracle_rel_err:.4f};"
                    f"within_5pct={rep.oracle_rel_err < 0.05}"),
            "arm": label,
            "config": rep.config,
        })
    # the FR/SRAM arm replays through the same controller now; assert its
    # oracle too (ROADMAP "irreversible arm still scalar" follow-up closed)
    fr = sim.run(sim.get_arm("FR+SRAM").with_workload(
        n_blocks=6, batch=48, spatial=7, c_branch=48, c_backbone=160),
        timing=timing)
    rows.append({
        "row": (f"bank_occupancy/FR+SRAM/oracle,0,"
                f"controller_j={fr.memory_j:.4e};"
                f"scalar_j={fr.scalar_memory_j:.4e};"
                f"rel_err={fr.oracle_rel_err:.4f};"
                f"within_5pct={fr.oracle_rel_err < 0.05};"
                f"offchip_kib={fr.offchip_bits/8/1024:.0f}"),
        "arm": "FR+SRAM",
        "config": fr.config,
    })
    # the hiding row's timeline report doubles as the bank-granularity
    # reference for the row_refresh comparison (no re-simulation) —
    # unless this whole run is itself row-granular
    rep = _append_hiding(rows, granularity=granularity)
    rows.append(_row_refresh_row(bank=rep if gran == "bank" else None))
    for f in (freqs or ()):
        rep = _append_hiding(rows, freq_hz=f, granularity=granularity)
        rows.append(_row_refresh_row(
            freq_hz=f, bank=rep if gran == "bank" else None))
    rows.append("bank_occupancy/claim,0,"
                "paper=selective refresh skips refresh-free banks (Fig 23) "
                "and beats always-refresh energy (Fig 24); timeline model "
                "hides refresh in bank-idle windows; hiding is "
                "frequency-dependent (--freq sweeps operating points) and "
                "row-granular pulses (--granularity row) hide where a "
                "whole-bank pulse cannot")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["row"] if isinstance(r, dict) else r)
