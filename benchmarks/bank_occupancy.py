"""Bank-level controller benchmark: per-bank occupancy, refresh counts,
and the selective-vs-always refresh energy delta (CAMEL §V, Figs 17/23/24)
across the Table III array sizes.

Each row replays the trace of one training iteration of the seed DuDNN
config (B6 + ResNet-50-scale backbone, batch 48) through ``repro.memory``
and cross-validates the controller totals against the scalar
``edram_energy`` oracle at the refresh-free operating point.
"""
from __future__ import annotations

from repro.core import hwmodel as hw, lifetime as lt

# seed DuDNN block configs (Table III / Fig 23-24 scale)
CONFIGS = [
    ("B6+R50", 6, 48, 48, 160),
    ("B4+R18", 4, 48, 32, 64),
]
ARRAYS = (6, 10, 12)           # Table III sweep
TEMPS = (60.0, 100.0)          # refresh-free point + mixed-lifetime point


def _controller(cfg: hw.SystemConfig, blocks) -> hw.IterationReport:
    return hw.iteration(cfg, blocks, reversible=True)


def run() -> list[str]:
    rows = []
    for label, nb, batch, cb, ck in CONFIGS:
        blocks = lt.duplex_block_specs(nb, batch=batch, spatial=7,
                                       c_branch=cb, c_backbone=ck)
        for array in ARRAYS:
            for temp in TEMPS:
                per_policy = {}
                for pol in ("none", "selective", "always"):
                    rep = _controller(
                        hw.SystemConfig(array=array, temp_c=temp,
                                        refresh_policy=pol,
                                        alloc_policy="lifetime"), blocks)
                    per_policy[pol] = rep
                sel = per_policy["selective"].controller
                alw = per_policy["always"].controller
                non = per_policy["none"].controller
                occ = [b.peak_occupancy for b in sel.banks]
                needs = sum(1 for b in sel.banks if b.needs_refresh)
                refreshed = sum(1 for b in sel.banks if b.refreshed)
                delta = alw.refresh_j - sel.refresh_j
                rows.append(
                    f"bank_occupancy/{label}/a{array}/T{temp:.0f},"
                    f"{per_policy['selective'].latency_s*1e6:.1f},"
                    f"occ_min={min(occ):.2f};occ_max={max(occ):.2f};"
                    f"needs_refresh={needs}/12;refreshed={refreshed};"
                    f"refresh_count={sel.refresh_count};"
                    f"sel_refresh_j={sel.refresh_j:.3e};"
                    f"always_refresh_j={alw.refresh_j:.3e};"
                    f"delta_j={delta:.3e};"
                    f"sel_lt_always={sel.refresh_j < alw.refresh_j};"
                    f"sel_ge_none={sel.refresh_j >= non.refresh_j};"
                    f"safe={sel.safe}")
        # oracle cross-validation at the refresh-free point: the replayed
        # totals must match the scalar edram_energy arithmetic within 5%
        rep = _controller(hw.SystemConfig(temp_c=60.0), blocks)
        ctrl_j = rep.memory_j
        oracle_j = rep.scalar_memory_j
        err = abs(ctrl_j - oracle_j) / max(oracle_j, 1e-30)
        rows.append(f"bank_occupancy/{label}/oracle,0,"
                    f"controller_j={ctrl_j:.4e};scalar_j={oracle_j:.4e};"
                    f"rel_err={err:.4f};within_5pct={err < 0.05}")
    rows.append("bank_occupancy/claim,0,"
                "paper=selective refresh skips refresh-free banks (Fig 23) "
                "and beats always-refresh energy (Fig 24)")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
