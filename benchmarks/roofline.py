"""§Roofline: three-term analysis per (arch × shape × mesh) from the dry-run.

    compute term    = dot_FLOPs_per_device / peak_FLOP/s
    memory term     = traffic_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / (links × link_bw)

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per direction), 4 ICI links per chip on a 2D torus (we budget traffic
against one link: conservative).  Also reports MODEL_FLOPS = 6·N·D (dense)
or 6·N_active·D (MoE) — fwd-only terms (2·N·D) for the frozen duplex
backbone — and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.common import SHAPES
from repro.models import registry
from repro.utils import count_params

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link


def param_counts(arch: str) -> dict:
    """Total & active parameter counts for MODEL_FLOPS (cached analytic)."""
    import jax
    entry = registry.get(arch)
    cfg = entry.full
    shapes = jax.eval_shape(lambda k: entry.module.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    total = count_params(shapes)
    active = total
    if cfg.n_experts:
        # only top_k (+shared) experts are active per token
        expert_params = cfg.n_experts * (cfg.d_model * cfg.d_ff *
                                         (3 if cfg.gated_mlp else 2))
        per_layer_moe = sum(1 for s in cfg.pattern if s.mlp == "moe")
        total_moe = expert_params * cfg.n_rep * per_layer_moe
        active_frac = cfg.top_k / cfg.n_experts
        active = total - total_moe * (1 - active_frac)
    return {"total": total, "active": active}


def model_flops(arch: str, shape_name: str, counts: dict) -> float:
    """Global useful FLOPs for the cell (duplex: fwd-only backbone)."""
    cfg = registry.get(arch).full
    shape = SHAPES[shape_name]
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        # frozen backbone forward (2·N·D) + branch fwd+bwd (6·n_branch·D/16)
        return 2.0 * counts["active"] * tokens
    if shape.mode == "prefill":
        return 2.0 * counts["active"] * shape.global_batch * shape.seq_len
    # decode: one token per sequence
    return 2.0 * counts["active"] * shape.global_batch


def load_cells(dryrun_dir: str = "experiments/dryrun") -> list[dict]:
    cells = []
    for p in sorted(Path(dryrun_dir).glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def roofline_row(rec: dict, counts: dict) -> dict:
    n_dev = rec["n_devices"]
    flops_dev = rec["cost"]["dot_flops"]          # already per device (SPMD)
    traffic_dev = rec["cost"]["traffic_bytes"]
    coll_dev = rec["collectives"].get("total", 0)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = traffic_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mflops = model_flops(rec["arch"], rec["shape"], counts)
    hlo_global = flops_dev * n_dev
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops": mflops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mflops / hlo_global if hlo_global else 0.0,
        "step_s_bound": max(terms.values()),
        # fraction of the step bound spent on MXU compute (1.0 ⇔ compute-bound)
        "compute_bound_fraction": (t_compute / max(terms.values())
                                   if max(terms.values()) > 0 else 0.0),
        # useful-model-FLOP/s at the bound, as a fraction of peak — §Perf score
        "roofline_fraction": (mflops / n_dev / max(terms.values()) / PEAK_FLOPS
                              if max(terms.values()) > 0 else 0.0),
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "args_gib": rec["memory"]["argument_bytes"] / 2**30,
    }


def build_table(dryrun_dir: str = "experiments/dryrun",
                mesh: str = "pod", variant: str = "baseline") -> list[dict]:
    counts_cache: dict = {}
    rows = []
    for rec in load_cells(dryrun_dir):
        if rec["mesh"] != mesh or rec.get("variant", "baseline") != variant:
            continue
        if rec["status"] == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "bottleneck": "SKIP",
                         "note": rec["reason"]})
            continue
        if rec["status"] != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "bottleneck": "ERROR"})
            continue
        if rec["arch"] not in counts_cache:
            counts_cache[rec["arch"]] = param_counts(rec["arch"])
        rows.append(roofline_row(rec, counts_cache[rec["arch"]]))
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bound | "
           "useful | roofline frac |\n|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("bottleneck") in ("SKIP", "ERROR"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['bottleneck']} | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return hdr + "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()
    rows = build_table(args.dryrun_dir, args.mesh, args.variant)
    Path(args.json_out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.json_out).write_text(json.dumps(rows, indent=2))
    print(markdown_table(rows))


if __name__ == "__main__":
    main()
