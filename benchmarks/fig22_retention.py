"""Fig 22: simulated 3T eDRAM worst-case retention vs temperature."""
from __future__ import annotations

from repro.core import edram as ed


def run() -> list[str]:
    rows = []
    for t in (-30, -10, 10, 30, 50, 70, 90, 100):
        rows.append(f"fig22/retention@{t}C,0,{ed.retention_s(t)*1e6:.2f}us")
    ok = abs(ed.retention_s(100) - 3.4e-6) < 1e-9 and \
        abs(ed.retention_s(-30) - 30e-6) < 1e-9
    rows.append(f"fig22/calibration,0,endpoints_match_paper={ok}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
