"""Table III: data lifetime vs systolic-array size (normalized to 6×6) —
sub-linear shrink because utilization drops on small layers.  Each array
point runs through ``repro.sim`` (the closed-form lifetimes cross-check
the reported ``max_lifetime_s`` in the tier-1 suite)."""
from __future__ import annotations

from repro import sim
from repro.core import lifetime as lt


def run() -> list:
    arm = sim.get_arm("DuDNN+CAMEL").with_workload(
        n_blocks=6, batch=48, spatial=7, c_branch=48, c_backbone=160)
    blocks = arm.resolve_blocks()
    specs = [s for b in blocks for s in (b.f1, b.f2, b.g)]
    base = None
    rows: list = []
    for a in (6, 10, 12):
        rep = sim.run(arm.with_system(array=a))
        life = rep.max_lifetime_s
        if base is None:
            base = life
        ratio = life / base
        ideal = (6 / a) ** 2
        # closed-form cross-check (eq 10) rides along in the derived field
        cf = lt.max_data_lifetime(blocks, lt.array_throughput(a, 500e6,
                                                              specs))
        rows.append({
            "row": (f"table3/array{a}x{a},0,"
                    f"lifetime={ratio:.2f}x;ideal={ideal:.2f}x;"
                    f"sublinear={ratio > ideal};"
                    f"closed_form_us={cf*1e6:.3f}"),
            "arm": rep.arm,
            "config": rep.config,
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["row"] if isinstance(r, dict) else r)
