"""Table III: data lifetime vs systolic-array size (normalized to 6×6) —
sub-linear shrink because utilization drops on small layers."""
from __future__ import annotations

from repro.core import lifetime as lt


def run() -> list[str]:
    blocks = lt.duplex_block_specs(6, batch=48, spatial=7, c_branch=48,
                                   c_backbone=160)
    specs = [s for b in blocks for s in (b.f1, b.f2, b.g)]
    base = None
    rows = []
    for a in (6, 10, 12):
        r = lt.array_throughput(a, 500e6, specs)
        life = lt.max_data_lifetime(blocks, r)
        if base is None:
            base = life
        ratio = life / base
        ideal = (6 / a) ** 2
        rows.append(f"table3/array{a}x{a},0,"
                    f"lifetime={ratio:.2f}x;ideal={ideal:.2f}x;"
                    f"sublinear={ratio > ideal}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
