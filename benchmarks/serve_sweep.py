"""Serving sweep: the Kelle-style KV-policy tradeoff, as CSV rows.

Sweeps the ``repro.serve`` arm family (``Serve/always`` ``Serve/skip``
``Serve/evict`` ``Serve/recompute`` — docs/serving.md) over an arrival-
rate axis and a temperature axis, so the two crossovers the subsystem
exists to show are plotted-as-CSV:

- **rate axis** (default-temperature rows): at low arrival rates
  sessions barely overlap, per-session decode gaps stay under the eDRAM
  retention floor and every policy costs about the same; as the rate
  climbs the continuous batch saturates, gaps stretch past retention,
  and ``evict``/``recompute`` diverge (evict drops context cheaply,
  recompute buys it back with MACs).
- **temperature axis** (``crossover`` rows): at 60 °C a single-session
  cache is re-read within retention, so ``skip`` (read-triggered
  restore, no pulses) beats ``always``; at 100 °C retention is shorter
  than the decode gap, ``skip`` degenerates into refresh *plus* restore
  overhead and ``always`` wins.

Rows: ``serve_sweep/<policy>@r<rate>,us_per_token,tokens_per_s=...``
plus one ``serve_sweep/crossover/T<temp>`` row per temperature naming
the measured winner.  ``run(trace_dir=...)`` (``--trace DIR``) captures
one flight-recorder run of ``Serve/skip``, reconciles it span-vs-report
(exact equality), and writes ``Serve_skip.trace.json`` for Perfetto /
``tools/check_trace.py``.

The committed record lives in ``BENCH_serve.json`` (repo root);
re-measure and append with::

    PYTHONPATH=src python -m benchmarks.serve_sweep --update
"""
from __future__ import annotations

import json
import pathlib
import time

from repro import obs, sim
from repro.obs import log
from repro.serve import KV_POLICIES

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_serve.json"

# arrival-rate axis (requests/s): sequential → default → saturated batch
RATES = (2.0e3, 2.0e4, 1.0e5)
# temperature-crossover axis (°C): retention 6.64 µs → 3.4 µs
CROSSOVER_TEMPS = (60.0, 100.0)


def _arms(rates=RATES):
    """The sweep grid as concrete arms, policy-major then rate."""
    return [sim.get_arm(f"Serve/{p}").with_traffic(arrival_per_s=r)
            for p in KV_POLICIES for r in rates]


def _policy_rows(timing, parallel, rates=RATES) -> list:
    arms = _arms(rates)
    flat = sim.sweep(arms, timing=timing, parallel=parallel)
    rows: list = []
    for arm, rep in zip(arms, flat):
        s = rep.serving
        us_per_token = 1e6 / s["tokens_per_s"] if s["tokens_per_s"] else 0.0
        tag = f"serve_sweep/{s['policy']}@r{s['arrival_per_s']:g}"
        rows.append({
            "row": (f"{tag},{us_per_token:.2f},"
                    f"tokens_per_s={s['tokens_per_s']:.0f};"
                    f"j_per_token={s['j_per_token']:.4e};"
                    f"energy_j={rep.energy_j:.4e};"
                    f"latency_p95_us={s['latency_p95_s']*1e6:.1f};"
                    f"evicted={s['kv_entries_evicted']};"
                    f"recomputed={s['kv_entries_recomputed']};"
                    f"reads_dropped={s['reads_dropped']};"
                    f"refresh_free={rep.refresh_free}"),
            "arm": rep.arm,
            "policy": s["policy"],
            "arrival_per_s": s["arrival_per_s"],
            "tokens_per_s": s["tokens_per_s"],
            "j_per_token": s["j_per_token"],
            "config": rep.config,
        })
    return rows


def _crossover_rows(timing, parallel) -> list:
    """always-vs-skip at each temperature, single-session traffic
    (``max_batch=1``, slow arrivals) so the decode gap — not batching —
    decides whether reads land inside retention."""
    arms = [sim.get_arm(f"Serve/{p}")
            .with_traffic(max_batch=1, arrival_per_s=2.0e3)
            for p in ("always", "skip")]
    flat = sim.sweep(arms, timing=timing, temps=list(CROSSOVER_TEMPS),
                     parallel=parallel)
    rows: list = []
    for j, temp in enumerate(CROSSOVER_TEMPS):
        rep = {arms[i].kv_policy: flat[i * len(CROSSOVER_TEMPS) + j]
               for i in range(len(arms))}
        winner = min(rep, key=lambda p: rep[p].energy_j)
        rows.append({
            "row": (f"serve_sweep/crossover/T{temp:g},0,"
                    f"winner={winner};"
                    f"always_j={rep['always'].energy_j:.4e};"
                    f"skip_j={rep['skip'].energy_j:.4e};"
                    f"skip_refresh_free={rep['skip'].refresh_free}"),
            "temp_c": temp,
            "winner": winner,
        })
    return rows


def _trace_rows(trace_dir) -> list:
    """One reconciled flight-recorder capture of the ``Serve/skip`` arm
    (timeline model — reconciliation is defined against its spans)."""
    out = pathlib.Path(trace_dir)
    out.mkdir(parents=True, exist_ok=True)
    arm = sim.get_arm("Serve/skip")
    rep = sim.run(arm, trace=True, timing="timeline")
    res = obs.reconcile(rep.trace, rep)
    path = out / (arm.name.replace("/", "_") + ".trace.json")
    obs.export_chrome_trace(rep.trace, path, report=rep)
    if not res.ok:
        log.error("trace_reconcile_mismatch", arm=arm.name,
                  detail=str(res))
    return [{
        "row": (f"serve_sweep/trace/{arm.name},0,"
                f"file={path.name};spans={len(rep.trace.spans)};"
                f"reconciled={res.ok}"),
        "arm": arm.name,
        "trace_file": str(path),
        "reconciled": res.ok,
    }]


def run(timing=None, parallel=None, trace_dir=None) -> list:
    rows = _policy_rows(timing, parallel)
    rows += _crossover_rows(timing, parallel)
    if trace_dir is not None:
        rows += _trace_rows(trace_dir)
    rows.append("serve_sweep/claim,0,paper=refresh-skipping wins while "
                "reads outpace retention; evict/recompute split past it")
    return rows


def measurements() -> list:
    """Per-policy headline numbers at the default traffic (the committed
    trajectory record: tokens/s and J/token per KV policy)."""
    out = []
    for policy in KV_POLICIES:
        rep = sim.run(sim.get_arm(f"Serve/{policy}"))
        s = rep.serving
        out.append({
            "policy": policy,
            "tokens_per_s": s["tokens_per_s"],
            "j_per_token": s["j_per_token"],
            "energy_j": rep.energy_j,
            "latency_s": rep.latency_s,
            "kv_entries_evicted": s["kv_entries_evicted"],
            "kv_entries_recomputed": s["kv_entries_recomputed"],
        })
    return out


def update_bench(path=BENCH_PATH) -> dict:
    """Append today's measurement to the committed trajectory file."""
    path = pathlib.Path(path)
    arm = sim.get_arm("Serve/always")
    data = (json.loads(path.read_text()) if path.exists()
            else {"benchmark": "serve_sweep",
                  "workload": {
                      "model": {"n_layers": arm.model.n_layers,
                                "d_model": arm.model.d_model,
                                "d_kv": arm.model.d_kv},
                      "traffic": {"seed": arm.traffic.seed,
                                  "n_requests": arm.traffic.n_requests,
                                  "arrival_per_s": arm.traffic.arrival_per_s,
                                  "max_batch": arm.traffic.max_batch},
                  },
                  "records": []})
    record = {"date": time.strftime("%Y-%m-%d"),
              "measurements": measurements()}
    data["records"].append(record)
    path.write_text(json.dumps(data, indent=1) + "\n")
    return record


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help=f"append a record to {BENCH_PATH.name}")
    args = ap.parse_args()
    if args.update:
        rec = update_bench()
        print(f"appended {rec['date']} record to {BENCH_PATH}")
    for r in run():
        print(r["row"] if isinstance(r, dict) else r)
