"""Shared benchmark scaffolding: the four accuracy arms of CAMEL Fig 20/24
(DuDNN / FR / CA / BO) at laptop scale on the synthetic bigram-LM task.

The scaled-down protocol: "pretrain" a small dense backbone on the task
distribution, freeze it, then train each arm's adapter for N steps with the
same budget.  The paper's qualitative claim to reproduce (Table II):
DuDNN ≈ FR  ≫  CA  ≫  BO.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.common import LayerSpec, ModelConfig
from repro.core import duplex as dx
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import layers as L, registry, transformer as T
from repro.optim import AdamWConfig
from repro.train import train_step as ts
from repro.train.losses import lm_cross_entropy

P32 = L.Policy(compute_dtype=jnp.float32)

BB_CFG = ModelConfig(
    name="bench-backbone", family="dense", vocab=256,
    d_model=64, n_layers=4, pattern=(LayerSpec("attn", "dense"),),
    n_heads=4, n_kv=4, head_dim=16, d_ff=128, vocab_pad_multiple=16,
).validate()

DATA = DataConfig(vocab=256, seq_len=64, batch_per_host=8, seed=0)


class _Entry:
    module = T
    full = BB_CFG
    smoke = BB_CFG

    @staticmethod
    def frontend_shape(cfg, batch):
        return None


def pretrain_backbone(steps: int = 150, key: int = 0):
    """The offline-pretrained backbone (paper §III-A)."""
    tcfg = ts.TrainConfig(mode="full", opt=AdamWConfig(weight_decay=0.0),
                          lr=3e-3)
    state = ts.init_state(jax.random.PRNGKey(key), _Entry, BB_CFG, tcfg, P32)
    step = jax.jit(ts.make_train_step(_Entry, BB_CFG, tcfg, P32))
    src = SyntheticLM(DATA)
    for i in range(steps):
        b = src.batch(i)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
    return state["backbone"], float(m["loss"])


def eval_arm(loss_fn, params, n_batches: int = 8, offset: int = 10_000):
    src = SyntheticLM(DATA)
    tot, acc = 0.0, 0.0
    for i in range(n_batches):
        b = {k: jnp.asarray(v) for k, v in src.batch(offset + i).items()}
        l, a = loss_fn(params, b)
        tot += float(l)
        acc += float(a)
    return tot / n_batches, acc / n_batches


def duplex_cfg(pool: int = 4, use_norm: bool = False,
               bfp: bool = True) -> dx.DuplexConfig:
    return dx.DuplexConfig(
        n_blocks=2, d_branch=32, pool_factor=pool, branch_heads=2,
        use_norm=use_norm,
        bfp=L.BFPPolicy(enabled=bfp, group=(3, 3)))


def train_arm(arm: str, backbone, steps: int = 200, key: int = 1,
              dcfg: dx.DuplexConfig | None = None):
    """Train one accuracy arm; returns (val_loss, val_acc, train_time_s).

    arms: duplex (taps from all depths) | chain (taps only from the final
    block — the CA baseline) | branch_only (zeroed taps & no backbone
    correction target — BO) | full (FR: finetune the whole backbone).
    """
    dcfg = dcfg or duplex_cfg()
    src = SyntheticLM(DATA)

    if arm == "full":
        tcfg = ts.TrainConfig(mode="full", opt=AdamWConfig(weight_decay=0.0),
                              lr=1e-3)
        state = ts.init_state(jax.random.PRNGKey(key), _Entry, BB_CFG, tcfg,
                              P32)
        state["backbone"] = jax.tree_util.tree_map(jnp.asarray, backbone)
        step = jax.jit(ts.make_train_step(_Entry, BB_CFG, tcfg, P32))
        t0 = time.time()
        for i in range(steps):
            b = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
            state, m = step(state, b)
        dt = time.time() - t0

        def loss_fn(params, batch):
            out = T.forward(params, BB_CFG, batch["tokens"], policy=P32)
            logits = T.lm_logits(params, BB_CFG, out["hidden"], P32)
            _, met = lm_cross_entropy(logits, batch["labels"])
            return met["loss"], met["accuracy"]

        l, a = eval_arm(loss_fn, state["backbone"])
        return l, a, dt

    n_rep = BB_CFG.n_rep
    if arm not in ("duplex", "chain", "branch_only"):
        raise ValueError(arm)
    idx = ts.tap_indices(n_rep, dcfg.n_blocks)

    branch = dx.duplex_init(jax.random.PRNGKey(key), dcfg, BB_CFG.d_model)
    from repro.optim import opt_init, opt_update
    opt_cfg = AdamWConfig(weight_decay=0.0)
    opt = opt_init(opt_cfg, branch)

    def loss_full(branch, batch):
        out = T.forward(backbone, BB_CFG, batch["tokens"], collect_taps=True,
                        tap_indices=idx, tap_pool=dcfg.pool_factor,
                        policy=P32)
        taps = out["taps"]
        if arm in ("branch_only", "chain"):
            # no intermediate-depth knowledge transfer (Fig 20 CA/BO)
            taps = jax.tree_util.tree_map(jnp.zeros_like, taps)
        # CA: the branch is chained AFTER the backbone — it consumes the
        # backbone output and fully replaces the head (no additive support)
        emb_in = out["hidden"] if arm == "chain" else out["emb"]
        corr = dx.duplex_apply(branch, dcfg, emb_in, taps, policy=P32,
                               taps_pooled=True)
        if arm == "duplex":
            hidden = jax.lax.stop_gradient(out["hidden"]) + corr
        else:
            hidden = corr
        logits = T.lm_logits(backbone, BB_CFG, hidden, P32)
        loss, met = lm_cross_entropy(logits, batch["labels"])
        return loss, met

    grad_fn = jax.value_and_grad(loss_full, has_aux=True)

    @jax.jit
    def step(branch, opt, batch):
        (loss, met), g = grad_fn(branch, batch)
        new_b, new_o, _ = opt_update(opt_cfg, g, opt, branch, 3e-3)
        return new_b, new_o, met

    t0 = time.time()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
        branch, opt, met = step(branch, opt, b)
    dt = time.time() - t0

    def eval_fn(params, batch):
        _, met = loss_full(params, batch)
        return met["loss"], met["accuracy"]

    l, a = eval_arm(eval_fn, branch)
    return l, a, dt
