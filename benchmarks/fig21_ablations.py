"""Fig 21 (scaled down): (a) pooling-factor ablation — halving the pool
factor quadruples branch compute for ~no accuracy gain; (b) norm-free branch
matches the normalized branch under backbone guidance."""
from __future__ import annotations

import time

from benchmarks import common


def run() -> list[str]:
    backbone, _ = common.pretrain_backbone(steps=150)
    rows = []

    # (a) pooling: pool=8 ("7×7") vs pool=4 ("14×14" — 4× the branch tokens)
    res = {}
    for pool in (8, 4):
        t0 = time.time()
        loss, acc, dt = common.train_arm(
            "duplex", backbone, steps=200, dcfg=common.duplex_cfg(pool=pool))
        res[pool] = (loss, acc, dt)
        rows.append(f"fig21a/pool{pool},{dt*1e6/200:.0f},"
                    f"loss={loss:.4f};acc={acc:.4f}")
    gain = res[8][0] - res[4][0]     # loss delta from 4× more branch compute
    rows.append(f"fig21a/verdict,0,loss_gain_from_4x_compute={gain:.4f}")

    # (b) normalization in the branch
    for use_norm in (False, True):
        loss, acc, dt = common.train_arm(
            "duplex", backbone, steps=200,
            dcfg=common.duplex_cfg(use_norm=use_norm))
        tag = "norm" if use_norm else "norm_free"
        rows.append(f"fig21b/{tag},{dt*1e6/200:.0f},"
                    f"loss={loss:.4f};acc={acc:.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
