"""Timeline-replay throughput: how many schedule ops per second the
closed-loop memory engine sustains on a large synthetic trace.

The ROADMAP's "raw speed" item needs a measured baseline before any
vectorization work: this suite builds a deterministic synthetic trace
(``n_ops`` ops in a produce→consume→free chain, every op touching
multiple banks) and times ``repro.sim.timeline.replay_timeline`` —
the full closed-loop walk + pulse placement + energy accounting — at
bank and row refresh granularity, with refresh forced on (``always``
policy, ~``TICKS`` retention ticks inside the trace) so the scheduler
does real placement work.

Rows: ``replay_throughput/<granularity>[+vector],us_per_op,...`` — one
pair per granularity (the reference ``python`` walk and the numpy
``vector`` interval engine, which must produce bit-identical reports;
``tests/test_replay_backends.py`` enforces that, this suite prices it).
A final pair of rows replays with a flight recorder attached
(``repro.obs.SpanRecorder``) to price the observation overhead, and on
a hybrid SRAM+eDRAM ``MemorySystem`` (``+tiered`` — an iso-area 0.25
split under ``lifetime_tiered`` routing, ``repro.memory.tiers``) to
price the tier-routing overhead — both always on the reference walk,
since a recorder or a tiered config downgrades ``vector``.

The committed record lives in ``BENCH_replay.json`` (repo root);
re-measure and append with::

    PYTHONPATH=src python -m benchmarks.replay_throughput --update

``--backend python|vector`` restricts the timed measurements to one
engine; ``tools/check_replay_bench.py`` gates CI on a fresh ``--json``
dump staying within 0.7x of the best committed record per mode.

Each record carries the date, commit-independent workload shape, and
ops/sec per granularity, so the trajectory stays comparable across PRs.
"""
from __future__ import annotations

import json
import pathlib
import time

from repro.core import hwmodel as hw
from repro.core.schedule import TraceEvent
from repro.memory.tiers import iso_area_tiers
from repro.obs.recorder import SpanRecorder
from repro.sim.timeline import replay_timeline

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_replay.json"

# synthetic workload shape (fixed: records stay comparable across PRs)
N_OPS = 2000
WORDS_PER_TENSOR = 4096          # ~4 rows at the default 1024-word rows
TICKS = 24                       # retention ticks inside the trace
FREQ_HZ = 500e6
TIER_SPLIT = 0.25                # SRAM area share of the tiered row


def synthetic_trace(n_ops: int = N_OPS,
                    words: int = WORDS_PER_TENSOR) -> tuple:
    """A produce→consume→free chain: op ``k`` writes tensor ``k``, reads
    tensor ``k-1``, frees tensor ``k-2`` — at most three tensors live, so
    the trace replays on the stock bank geometry at any length.  Returns
    ``(events, op_schedule, duration_s, cfg)``."""
    cfg = hw.SystemConfig().edram
    bits = float(words * cfg.word_bits)
    # op duration ~ the port service time of its traffic, so the walk's
    # busy intervals and idle gaps are both non-trivial
    dt = 2.0 * words / FREQ_HZ
    events: list = []
    op_schedule: list = []
    for k in range(n_ops):
        t, op = k * dt, f"op{k}"
        op_schedule.append((op, t, t + dt))
        events.append(TraceEvent(time=t, op=op, tensor=f"t{k}",
                                 kind="write", bits=bits))
        if k >= 1:
            events.append(TraceEvent(time=t, op=op, tensor=f"t{k-1}",
                                     kind="read", bits=bits))
        if k >= 2:
            events.append(TraceEvent(time=t, op=op, tensor=f"t{k-2}",
                                     kind="free", bits=bits))
    return events, op_schedule, n_ops * dt, cfg


def _measure(granularity: str, recorder=None, n_ops: int = N_OPS,
             backend: str = "python", tiered: bool = False) -> dict:
    """One timed replay; returns the measurement record (no I/O)."""
    events, op_schedule, duration_s, cfg = synthetic_trace(n_ops)
    tiers = iso_area_tiers(cfg, TIER_SPLIT) if tiered else None
    policy = "lifetime_tiered" if tiered else "pingpong"
    t0 = time.perf_counter()
    rep = replay_timeline(
        events, cfg, op_schedule=op_schedule, temp_c=100.0,
        duration_s=duration_s, refresh_policy="always",
        alloc_policy=policy, freq_hz=FREQ_HZ,
        retention_s=duration_s / TICKS, granularity=granularity,
        recorder=recorder, backend=backend, tiers=tiers)
    wall = time.perf_counter() - t0
    return {
        "granularity": granularity,
        "backend": backend,
        "traced": recorder is not None,
        "tiered": tiered,
        "n_ops": n_ops,
        "events": len(events),
        "wall_s": wall,
        "ops_per_s": n_ops / wall if wall > 0 else 0.0,
        "pulses": rep.timeline["pulses"],
        "spans": len(recorder.spans) if recorder is not None else 0,
    }


def measurements(n_ops: int = N_OPS, backends=("python", "vector")) -> list:
    out = []
    for backend in backends:
        # discarded warmup: the first replay in a process pays module
        # imports and numpy dispatch setup (~2x on the vector engine),
        # which would gate on process start order instead of throughput
        _measure("bank", n_ops=min(n_ops, 100), backend=backend)
        out.append(_measure("bank", n_ops=n_ops, backend=backend))
        out.append(_measure("row", n_ops=n_ops, backend=backend))
    if "python" in backends:
        # tracing forces the reference walk (vector downgrades), so the
        # observation-overhead row only exists for the python engine
        out.append(_measure("bank", recorder=SpanRecorder(), n_ops=n_ops))
        # likewise the hybrid SRAM+eDRAM MemorySystem needs the
        # reference walk: this row prices the tier-routing overhead
        out.append(_measure("bank", n_ops=n_ops, tiered=True))
    return out


def mode_tag(m: dict) -> str:
    """The stable row/mode key for one measurement record."""
    return (m["granularity"]
            + ("+vector" if m.get("backend") == "vector" else "")
            + ("+trace" if m["traced"] else "")
            + ("+tiered" if m.get("tiered") else ""))


def run() -> list:
    """Benchmark-harness entry (``benchmarks.run --only replay``)."""
    rows = []
    for m in measurements():
        tag = mode_tag(m)
        rows.append({
            "row": (f"replay_throughput/{tag},"
                    f"{m['wall_s'] / m['n_ops'] * 1e6:.2f},"
                    f"ops_per_s={m['ops_per_s']:.0f};"
                    f"n_ops={m['n_ops']};events={m['events']};"
                    f"pulses={m['pulses']};spans={m['spans']}"),
            "granularity": m["granularity"],
            "backend": m["backend"],
            "ops_per_s": m["ops_per_s"],
        })
    return rows


def update_bench(path=BENCH_PATH) -> dict:
    """Append today's measurement to the committed trajectory file."""
    path = pathlib.Path(path)
    data = (json.loads(path.read_text()) if path.exists()
            else {"benchmark": "replay_throughput",
                  "workload": {"n_ops": N_OPS,
                               "words_per_tensor": WORDS_PER_TENSOR,
                               "ticks": TICKS, "freq_hz": FREQ_HZ},
                  "records": []})
    record = {"date": time.strftime("%Y-%m-%d"),
              "measurements": measurements()}
    data["records"].append(record)
    path.write_text(json.dumps(data, indent=1) + "\n")
    return record


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help=f"append a record to {BENCH_PATH.name}")
    ap.add_argument("--backend", choices=("python", "vector", "all"),
                    default="all",
                    help="restrict timed measurements to one replay "
                         "engine (default: both)")
    ap.add_argument("--json", type=pathlib.Path, metavar="PATH",
                    help="dump the measurement records as JSON (the "
                         "input tools/check_replay_bench.py gates on)")
    args = ap.parse_args()
    if args.update:
        rec = update_bench()
        print(f"appended {rec['date']} record to {BENCH_PATH}")
    backends = (("python", "vector") if args.backend == "all"
                else (args.backend,))
    ms = measurements(backends=backends)
    if args.json:
        args.json.write_text(json.dumps(ms, indent=1) + "\n")
    for m in ms:
        print(f"replay_throughput/{mode_tag(m)},"
              f"{m['wall_s'] / m['n_ops'] * 1e6:.2f},"
              f"ops_per_s={m['ops_per_s']:.0f};"
              f"n_ops={m['n_ops']};events={m['events']};"
              f"pulses={m['pulses']};spans={m['spans']}")
