"""Table II (scaled down): accuracy ordering DuDNN ≈ FR ≫ CA ≫ BO.

Full CIFAR/Tiny-ImageNet training is out of scope on CPU; the protocol keeps
the paper's *structure* (pretrained frozen backbone, equal adapter budgets,
identical steps) on the synthetic bigram-LM task and validates the ordering
the paper reports.
"""
from __future__ import annotations

import time

from benchmarks import common


def run() -> list[str]:
    t0 = time.time()
    backbone, pre_loss = common.pretrain_backbone(steps=150)
    rows = []
    results = {}
    for arm in ("duplex", "full", "chain", "branch_only"):
        loss, acc, dt = common.train_arm(arm, backbone, steps=200)
        results[arm] = (loss, acc)
        rows.append(f"table2/{arm},{dt*1e6/200:.0f},"
                    f"loss={loss:.4f};acc={acc:.4f}")

    # the paper's ordering (Table II): DuDNN ≈ FR  ≫  CA  ≫  BO
    d, f = results["duplex"][0], results["full"][0]
    c, b = results["chain"][0], results["branch_only"][0]
    ok_df = d <= f * 1.15          # DuDNN within 15% of full finetune
    ok_dc = d < c                  # beats chain
    ok_cb = c < b                  # chain beats branch-only
    rows.append(f"table2/ordering,{(time.time()-t0)*1e6:.0f},"
                f"DuDNN~FR={ok_df};DuDNN<CA={ok_dc};CA<BO={ok_cb}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
