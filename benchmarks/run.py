"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The roofline table (from the
multi-pod dry-run artifacts) is appended when ``experiments/dryrun`` exists.
``--json PATH`` additionally writes the rows as machine-readable records
({"name", "us_per_call", "derived", "suite", ...}) for perf-trajectory
tracking; suites that simulate a system arm attach the arm name and its
fully resolved config (``repro.sim.ArmReport.config``), so each record is
self-describing.  ``--list`` prints the registered suites.

``--timing additive|timeline`` selects the memory stall model,
``--parallel N`` the ``sim.sweep`` process-pool width,
``--freq F1,F2,...`` an operating-point axis (Hz, e.g. ``2.5e8,5e8`` —
each becomes a ``FixedClock`` cost model), and ``--granularity bank|row``
the refresh pulse unit (row-granular pulses interleave with compute at
wordline boundaries); all are forwarded to the suites that accept them
(currently fig24 and bank_occupancy).  ``--trace DIR`` captures
flight-recorder traces for the suites that support it (fig24 writes one
reconciled Chrome-trace JSON per arm; open in Perfetto, validate with
``tools/check_trace.py`` — see ``docs/observability.md``).  Rows from a
frequency sweep carry a top-level ``freq_hz`` field in the ``--json``
records — and the granularity-aware rows a ``granularity`` /
``refresh_stall_s`` pair — so sweep outputs stay machine-comparable
across PRs.  Diagnostics (refresh warnings, sweep progress) go through
``repro.obs.log`` to stderr (level via the ``REPRO_LOG`` env var),
keeping stdout pure CSV.

    PYTHONPATH=src python -m benchmarks.run [--only fig24] [--skip-slow]
                                            [--json out.json] [--list]
                                            [--timing timeline]
                                            [--parallel 4]
                                            [--freq 2.5e8,5e8]
                                            [--granularity row]
                                            [--trace traces/]
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time
import traceback

from benchmarks import (bank_occupancy, bfp_fidelity, fig21_ablations,
                        fig22_retention, fig23_lifetime, fig24_tta_eta,
                        replay_throughput, serve_sweep, table2_accuracy,
                        table3_arraysize, tier_sweep)

SUITES = {
    "table2": table2_accuracy.run,      # accuracy arms (slow-ish: trains)
    "fig21": fig21_ablations.run,       # pooling / norm ablations
    "fig22": fig22_retention.run,       # eDRAM retention curve
    "fig23": fig23_lifetime.run,        # per-layer data lifetime
    "fig24": fig24_tta_eta.run,         # TTA / ETA vs baselines
    "table3": table3_arraysize.run,     # array size vs lifetime
    "bfp": bfp_fidelity.run,            # §III-E fidelity + kernel timing
    "bank_occupancy": bank_occupancy.run,   # repro.memory controller
    "replay": replay_throughput.run,    # timeline-engine ops/sec
    "serve_sweep": serve_sweep.run,     # KV-policy serving tradeoff
    "tier_sweep": tier_sweep.run,       # iso-area SRAM:eDRAM hybrid
}
SLOW = {"table2", "fig21", "bfp"}       # these train models on CPU


def _row_record(row, suite: str = "") -> dict:
    """A suite row — either a bare CSV string or a dict carrying the CSV
    under "row" plus extra record fields (arm name, resolved config) —
    as one JSON record."""
    extras = {}
    if isinstance(row, dict):
        extras = {k: v for k, v in row.items() if k != "row"}
        row = row["row"]
    parts = row.split(",", 2) + ["", ""]          # tolerate short rows
    name, us, derived = parts[0], parts[1], parts[2]
    try:
        us_val: float = float(us)
    except ValueError:
        us_val = 0.0
    return {"name": name, "us_per_call": us_val, "derived": derived,
            "suite": suite, **extras}


def _roofline_rows() -> list[str]:
    from pathlib import Path
    if not Path("experiments/dryrun").exists():
        return ["roofline/skipped,0,no experiments/dryrun artifacts"]
    from benchmarks import roofline
    rows = []
    for r in roofline.build_table("experiments/dryrun", mesh="pod"):
        if r.get("bottleneck") in ("SKIP", "ERROR"):
            rows.append(f"roofline/{r['arch']}/{r['shape']},0,"
                        f"{r['bottleneck']}")
            continue
        rows.append(
            f"roofline/{r['arch']}/{r['shape']},"
            f"{r['step_s_bound']*1e6:.0f},"
            f"bound={r['bottleneck']};frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_ratio']:.2f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--skip-slow", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON records to PATH")
    ap.add_argument("--list", action="store_true",
                    help="print registered suites and exit")
    ap.add_argument("--timing", default=None,
                    choices=["additive", "timeline"],
                    help="memory stall model for suites that sim arms "
                         "(default: the sim default, timeline)")
    ap.add_argument("--parallel", default=None, type=int, metavar="N",
                    help="sim.sweep process-pool width for suites that "
                         "support it")
    ap.add_argument("--freq", default=None, metavar="F1,F2,...",
                    help="comma-separated operating frequencies in Hz "
                         "(each a FixedClock point) for suites that sweep "
                         "them; records carry freq_hz")
    ap.add_argument("--granularity", default=None,
                    choices=["bank", "row"],
                    help="refresh pulse unit for suites that sim arms "
                         "(row = per-wordline pulses; default: the "
                         "system default, bank)")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="flight-recorder capture directory for suites "
                         "that support it (fig24 writes one Chrome-trace "
                         "JSON per arm; open in Perfetto, validate with "
                         "tools/check_trace.py)")
    args = ap.parse_args()
    freqs = ([float(f) for f in args.freq.split(",")]
             if args.freq else None)

    if args.list:
        for name in (*SUITES, "roofline"):
            slow = " (slow)" if name in SLOW else ""
            print(f"{name}{slow}")
        return

    names = list(SUITES) if not args.only else args.only.split(",")
    failures = 0
    records = []
    suite = ""

    def emit(row) -> None:
        records.append(_row_record(row, suite=suite))
        print(row["row"] if isinstance(row, dict) else row)

    print("name,us_per_call,derived")
    for name in names:
        if name == "roofline":
            continue
        suite = name
        if args.skip_slow and name in SLOW:
            emit(f"{name}/skipped,0,--skip-slow")
            continue
        t0 = time.time()
        try:
            # forward --timing/--parallel/--freq to suites whose run()
            # accepts them
            accepted = inspect.signature(SUITES[name]).parameters
            kwargs = {k: v for k, v in (("timing", args.timing),
                                        ("parallel", args.parallel),
                                        ("freqs", freqs),
                                        ("granularity", args.granularity),
                                        ("trace_dir", args.trace))
                      if v is not None and k in accepted}
            for row in SUITES[name](**kwargs):
                emit(row)
            emit(f"{name}/suite_wall,{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:
            failures += 1
            traceback.print_exc()
            emit(f"{name}/suite_wall,{(time.time()-t0)*1e6:.0f},"
                 f"ERROR:{type(e).__name__}")
    if args.only is None or "roofline" in args.only:
        suite = "roofline"
        for row in _roofline_rows():
            emit(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
