"""2D BFP (§III-E) numeric fidelity + kernel timing: quantization error of
the paper format, transpose invariance, BFP-vs-fp32 training parity, and
interpret-mode kernel call cost (CPU; on-TPU timing needs hardware)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import bfp
from repro.kernels.bfp_matmul import bfp_matmul


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 256))

    for group, mbits in (((3, 3), 5), ((32, 32), 5), ((3, 3), 7)):
        rmse = float(bfp.quantization_rmse(x, group=group, mbits=mbits))
        t = bfp.bfp_quantize(x, group=group, mbits=mbits)
        rows.append(f"bfp/rmse_g{group[0]}m{mbits},0,"
                    f"rmse={rmse:.5f};bits={t.bits_per_value:.2f}")

    # transpose invariance (the §III-E property)
    q1 = bfp.bfp_dequantize(bfp.bfp_quantize(x.T))
    q2 = bfp.bfp_dequantize(bfp.bfp_quantize(x)).T
    rows.append(f"bfp/transpose_invariance,0,"
                f"max_diff={float(jnp.max(jnp.abs(q1-q2))):.2e}")

    # kernel call time (interpret mode — correctness path on CPU)
    a, b = jax.random.normal(key, (128, 128)), jax.random.normal(key, (128, 128))
    f = lambda: bfp_matmul(a, b, group=32, block_m=64, block_n=64,
                           block_k=64, interpret=True).block_until_ready()
    f()
    t0 = time.time()
    for _ in range(3):
        f()
    rows.append(f"bfp/pallas_matmul_128_interp,{(time.time()-t0)/3*1e6:.0f},"
                f"oracle=ref.ref_bfp_matmul")

    # end-to-end: duplex training with paper-format BFP vs fp32 branch
    backbone, _ = common.pretrain_backbone(steps=120)
    l_fp, a_fp, _ = common.train_arm("duplex", backbone, steps=150,
                                     dcfg=common.duplex_cfg(bfp=False))
    l_q, a_q, _ = common.train_arm("duplex", backbone, steps=150,
                                   dcfg=common.duplex_cfg(bfp=True))
    rows.append(f"bfp/training_parity,0,"
                f"fp32_loss={l_fp:.4f};bfp_loss={l_q:.4f};"
                f"gap={(l_q-l_fp):.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
