"""Fig 23(a): per-layer maximum data lifetime of Branch-6+ResNet-50 during
training, against the 3.4 µs @ 100 °C retention floor — the co-design
criterion that makes eDRAM refresh-free."""
from __future__ import annotations

from repro.core import edram as ed, lifetime as lt


def run() -> list[str]:
    # Branch-6 + ResNet-50-scale backbone, pooled 7×7 (paper §VI-B/D)
    blocks = lt.duplex_block_specs(n_blocks=6, batch=1, spatial=7,
                                   c_branch=48, c_backbone=160)
    specs = [s for b in blocks for s in (b.f1, b.f2, b.g)]
    R = lt.array_throughput(6, 500e6, specs)
    fwd = lt.forward_lifetimes(blocks, R)
    bwd = lt.backward_lifetimes(blocks, R)
    floor = ed.retention_s(100.0)
    rows = []
    worst = 0.0
    for l, (f, b) in enumerate(zip(fwd, bwd)):
        life = max(max(f.values()), max(b.values()))
        worst = max(worst, life)
        rows.append(f"fig23/layer{l},0,lifetime={life*1e6:.3f}us")
    rows.append(f"fig23/criterion,0,max={worst*1e6:.3f}us;"
                f"retention@100C={floor*1e6:.2f}us;refresh_free={worst < floor}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
