"""Fig 23(a): per-layer maximum data lifetime of Branch-6+ResNet-50 during
training, against the 3.4 µs @ 100 °C retention floor — the co-design
criterion that makes eDRAM refresh-free.  The closed forms give the
per-layer bars; the ``repro.sim`` pipeline gives the end-to-end verdict
(the bank-level controller's refresh-free check at 100 °C)."""
from __future__ import annotations

from repro import sim
from repro.core import edram as ed, lifetime as lt


def run() -> list:
    # Branch-6 + ResNet-50-scale backbone, pooled 7×7 (paper §VI-B/D)
    blocks = lt.duplex_block_specs(n_blocks=6, batch=1, spatial=7,
                                   c_branch=48, c_backbone=160)
    specs = [s for b in blocks for s in (b.f1, b.f2, b.g)]
    R = lt.array_throughput(6, 500e6, specs)
    fwd = lt.forward_lifetimes(blocks, R)
    bwd = lt.backward_lifetimes(blocks, R)
    floor = ed.retention_s(100.0)
    rows: list = []
    worst = 0.0
    for l, (f, b) in enumerate(zip(fwd, bwd)):
        life = max(max(f.values()), max(b.values()))
        worst = max(worst, life)
        rows.append(f"fig23/layer{l},0,lifetime={life*1e6:.3f}us")
    rows.append(f"fig23/criterion,0,max={worst*1e6:.3f}us;"
                f"retention@100C={floor*1e6:.2f}us;refresh_free={worst < floor}")
    # the bank-level verdict also tracks iteration-long residents (weight
    # gradient accumulators), which the per-layer closed forms exclude —
    # selective refresh confines them to a few banks and keeps them safe
    rep = sim.run(sim.get_arm("DuDNN+CAMEL")
                  .with_workload(n_blocks=6, batch=1, spatial=7,
                                 c_branch=48, c_backbone=160)
                  .with_system(temp_c=100.0, alloc_policy="lifetime"))
    refreshed = sum(1 for b in rep.memory["banks"] if b["refreshed"])
    rows.append({
        "row": (f"fig23/controller,0,"
                f"max_activation={rep.max_lifetime_s*1e6:.3f}us;"
                f"fully_refresh_free={rep.refresh_free};"
                f"banks_refreshed={refreshed}/{len(rep.memory['banks'])};"
                f"refresh_j={rep.memory['refresh_j']:.3e};"
                f"safe={rep.memory['safe']}"),
        "arm": rep.arm,
        "config": rep.config,
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["row"] if isinstance(r, dict) else r)
